"""The fleet driver: N pipeline replicas behind one routed arrival queue.

A :class:`Cluster` owns one :class:`Replica` per pipeline — each with
its *own* :class:`~repro.schedulers.runtime.RebalanceRuntime` (detector
state, exploration phases), its own executor (interference timeline /
slowdown schedule) and its own admission ledger — plus one
:class:`~repro.cluster.base.Router`.  :meth:`Cluster.run` (or the
functional :func:`run_cluster`) drives the shared arrival queue: the
workload generates *fleet* arrivals, the router picks a replica per
arrival, and the query is served through that replica's
:class:`~repro.workloads.runner.PipelineRunner` — the same event-loop
code ``run_pipeline`` drives for a single pipeline, fed one query at a
time so routing decisions always see up-to-date replica state.

Closed-loop semantics generalize per replica: a query dispatched to
replica ``r`` arrives the instant ``r`` can take it, and the router's
notion of "now" is the earliest admission-head free time across the
fleet — with ``n = 1`` this reduces *bit-identically* to the
single-pipeline closed loop (tests/test_cluster.py).
"""
from __future__ import annotations

import dataclasses
import heapq
import inspect
import math
from typing import Callable, List, Optional, Sequence, Tuple, Union

import numpy as np

from repro.cluster.base import ReplicaView, Router
from repro.cluster.registry import resolve_router
from repro.cluster.trace import ClusterTrace
from repro.control.base import AdmissionView
from repro.control.registry import resolve_admission, resolve_autoscaler
from repro.faults.health import OPEN, HealthTracker
from repro.faults.retry import RetrySpec, resolve_retries
from repro.qos import QosRequest, TierPlan, resolve_tiers
from repro.schedulers.runtime import RebalanceRuntime
from repro.util.errors import TransientQueryError
from repro.telemetry.streaming import (
    DEFAULT_SINK_INTERVAL,
    StreamingClusterTrace,
    StreamingCollector,
)
from repro.workloads.base import QueryExecutor, Workload
from repro.workloads.runner import PipelineRunner, resolve_arrivals


def _fleet_snapshot(runners, extra: Optional[StreamingCollector],
                    slo: float, num_active: int) -> dict:
    """Aggregate per-replica collectors into one fleet snapshot for the
    sink: counters sum exactly, sketches merge within tolerance."""
    agg = StreamingCollector(slo=slo)
    for runner in runners:
        runner.flush_telemetry()
        agg.absorb(runner.telemetry)
    if extra is not None:
        agg.absorb(extra)
    reg = agg.registry
    reg.gauge("num_replicas", "fleet size").set(len(runners))
    reg.gauge("active_replicas",
              "replicas in the routed set").set(num_active)
    return reg.snapshot()


@dataclasses.dataclass
class Replica:
    """One pipeline behind the router.

    ``on_assign(fleet_q, local_q, arrival)`` — optional backend hook
    invoked when a fleet query is routed here, *before* it executes:
    the live backend appends the query's token array to the replica's
    local stream, the time-indexed simulator backend appends the
    arrival time to the replica's clock (``arrival`` is ``None`` for a
    closed loop).  ``peak_throughput`` is the replica's
    interference-free reference for SLO accounting (NaN = unknown; the
    live backend stamps it post-run).
    """

    executor: QueryExecutor
    runtime: RebalanceRuntime
    name: str = ""
    peak_throughput: float = float("nan")
    #: Replica pool label (heterogeneous fleets, docs/QOS.md):
    #: ``"small"`` marks a small-model replica the ``downgrade`` router
    #: may send best-effort traffic to under pressure.
    pool: str = "default"
    on_assign: Optional[Callable[[int, int, Optional[float]], None]] = None
    #: optional recovery hook ``on_recover(now)`` — fired once per
    #: breaker open->probe transition, *before* the probe dispatch: the
    #: live backend re-warms its XLA shape buckets off the timed path
    #: (docs/FAULTS.md), the simulator backend needs nothing.
    on_recover: Optional[Callable[[float], None]] = None


class Cluster:
    """N replicas + one router; reusable across serving windows.

    The SLO control plane (``repro.control``, docs/CONTROL.md) hooks in
    at the fleet level: an ``admission`` policy may shed an arrival
    *after* routing (the decision sees the chosen replica's predicted
    wait and service estimate — if the best replica cannot meet the
    SLO, nobody can), and an ``autoscaler`` decides per arrival which
    replicas are active — the router only ever sees the active subset,
    so a drained replica simply stops receiving work and finishes its
    backlog.  Defaults (no admission policy, no autoscaler) leave the
    fleet loop bit-identical to the pre-control-plane cluster.

    ``max_batch > 1`` opts into fleet rebatching: consecutive open-loop
    arrivals routed to the *same* replica are buffered and flushed
    through that replica's :meth:`~repro.workloads.runner.PipelineRunner.
    step_many` — the routed backlog re-forms into batches (one set of
    stage dispatches per streak) instead of executing query-by-query.
    Buffered queries count in every :class:`ReplicaView`'s
    ``outstanding`` so routing stays load-aware, but ledger-derived
    estimates (``backlog``, ``free_at``) trail the unflushed tail by up
    to ``max_batch - 1`` queries.  The default ``max_batch = 1`` is the
    exact pre-rebatching path (every query steps immediately), and a
    closed loop never buffers — its decision clock needs each query's
    completion.

    Note: ``adaptive_batch`` has no effect at the fleet level — the
    rebatch streak length is capped by routing locality and
    ``max_batch``, not by a steered bound.
    """

    def __init__(self, replicas: Sequence[Replica],
                 router: Union[str, Router, None] = "round_robin",
                 router_kwargs: Optional[dict] = None,
                 admission: Union[str, object, None] = None,
                 admission_kwargs: Optional[dict] = None,
                 autoscaler: Union[str, object, None] = None,
                 autoscaler_kwargs: Optional[dict] = None,
                 max_batch: int = 1,
                 retries: Union[RetrySpec, int, dict, None] = None,
                 hedge_after: Optional[float] = None,
                 health_kwargs: Optional[dict] = None,
                 when_all_unhealthy: str = "wait",
                 tiers=None,
                 tiers_kwargs: Optional[dict] = None):
        if len(replicas) < 1:
            raise ValueError("a cluster needs at least one replica")
        if when_all_unhealthy not in ("wait", "shed"):
            raise ValueError(f"when_all_unhealthy must be 'wait' or "
                             f"'shed', got {when_all_unhealthy!r}")
        self.replicas = list(replicas)
        self.max_batch = max(1, int(max_batch))
        # -- fault tolerance (repro.faults; docs/FAULTS.md) ------------------
        self.retries = resolve_retries(retries)
        self.hedge_after = (None if hedge_after is None
                            else float(hedge_after))
        self.health_kwargs = dict(health_kwargs or {})
        self.when_all_unhealthy = when_all_unhealthy
        #: the fleet loop arms its recovery machinery when retries are
        #: configured, hedging is on, or any replica injects faults.
        self.fault_aware = (self.retries is not None
                            or self.hedge_after is not None
                            or any(getattr(rep.executor, "injects_faults",
                                           False) for rep in self.replicas))
        if self.fault_aware and self.retries is None:
            self.retries = RetrySpec()     # default budget (docs/FAULTS.md)
        # Faults + rebatching compose: a failure inside a flushed batch
        # is attributed to a single query (fault-window chunks are
        # single-query by construction) and handled per
        # ``RetrySpec.batch_policy``; with hedging on, whole buffered
        # dispatches are duplicated (docs/FAULTS.md "Hedged batched
        # dispatch") — the loser replica is charged the dispatch's full
        # span as wasted work, so the hedge/rebatch composition keeps
        # honest occupancy accounting.
        # QoS tiers (repro.qos, docs/QOS.md): the spec is resolved into
        # a fleet TierPlan per run (stamping needs the run length).
        self._tiers_spec = tiers
        self._tiers_kwargs = tiers_kwargs
        self.router = resolve_router(router, router_kwargs)
        self.router_name = getattr(self.router, "name",
                                   type(self.router).__name__)
        self.admission = resolve_admission(admission, admission_kwargs)
        self.admission_name = ("none" if self.admission is None
                               else getattr(self.admission, "name",
                                            type(self.admission).__name__))
        # None = autoscaling disabled (all replicas always active) —
        # same behaviour as the "static" built-in, without threading a
        # policy object through the fleet loop at all.
        if autoscaler is None and autoscaler_kwargs:
            raise ValueError("autoscaler_kwargs given but no autoscaler "
                             "selected")
        self.autoscaler = (None if autoscaler is None
                           else resolve_autoscaler(autoscaler,
                                                   autoscaler_kwargs))
        self.autoscaler_name = ("static" if self.autoscaler is None
                                else getattr(self.autoscaler, "name",
                                             type(self.autoscaler).__name__))

    def run(self, num_queries: int,
            workload: Union[str, Workload, None] = "closed",
            workload_kwargs: Optional[dict] = None,
            scheduler_name: str = "",
            trace_mode: str = "dense",
            metrics_sink=None,
            sink_interval: Optional[int] = None
            ) -> Union[ClusterTrace, StreamingClusterTrace]:
        """Serve ``num_queries`` fleet arrivals of ``workload`` through
        the routed replicas; returns a :class:`ClusterTrace`.

        Per arrival: pop completed work from each replica's
        outstanding ledger, build the :class:`ReplicaView` snapshots,
        ask the router, fire the backend's ``on_assign`` hook, and
        serve the query through the chosen replica's runner (advancing
        its environment, polling its scheduler runtime, accounting its
        arrival queue — identical per-query semantics to
        ``run_pipeline``).

        ``trace_mode="streaming"`` (docs/TELEMETRY.md) runs every
        replica at flat memory and returns a
        :class:`~repro.telemetry.StreamingClusterTrace` — same
        ``summary()`` keys, fleet percentiles from merged per-replica
        sketches.  ``metrics_sink`` receives fleet-aggregated snapshots
        every ``sink_interval`` arrivals in either mode.
        """
        if trace_mode not in ("dense", "streaming"):
            raise ValueError(f"unknown trace_mode {trace_mode!r}; "
                             f"expected 'dense' or 'streaming'")
        streaming = trace_mode == "streaming"
        wl_name, arrivals = resolve_arrivals(workload, workload_kwargs,
                                             num_queries)

        adm = self.admission
        slo = float(getattr(adm, "slo", float("inf"))
                    if adm is not None else float("inf"))
        use_telemetry = streaming or metrics_sink is not None
        # Fleet-level sheds never reach a replica, so they get their
        # own collector (merged into the fleet view at read time).
        fleet_extra = StreamingCollector(slo=slo) if use_telemetry else None

        # QoS tiers (docs/QOS.md): one fleet plan indexed by fleet
        # arrival; each replica gets an empty local plan its assigned
        # queries are stamped into (keyed overwrite, like on_assign).
        tier_plan = None
        if self._tiers_spec is not None or self._tiers_kwargs:
            tier_plan = resolve_tiers(self._tiers_spec,
                                      self._tiers_kwargs, num_queries)
        if tier_plan is not None and fleet_extra is not None:
            fleet_extra.configure_tiers(tier_plan.names)
        # Tier-aware routers take the arrival's QoS context through an
        # optional ``request`` keyword, detected once by signature
        # (routers without it are called exactly as before).
        try:
            wants_request = "request" in inspect.signature(
                self.router.route).parameters
        except (TypeError, ValueError):
            wants_request = False
        # Downgrade accounting is read as a per-run delta: the router
        # object persists across serving windows.
        dg_before = dict(getattr(self.router, "downgrade_counts", None)
                         or {})

        # Pre-size each runner at its balanced share; a skewed router
        # just grows that replica's arrays (doubling) as it serves —
        # streaming runners stay at their fixed recycling capacity.
        share = -(-num_queries // len(self.replicas))
        runners = [PipelineRunner(rep.executor, rep.runtime, share,
                                  trace_mode=trace_mode,
                                  telemetry=(StreamingCollector(slo=slo)
                                             if use_telemetry else None),
                                  tiers=(TierPlan.empty(tier_plan.tiers,
                                                        share)
                                         if tier_plan is not None
                                         else None))
                   for rep in self.replicas]
        # Outstanding completions per replica: popped against the
        # (monotone) decision clock to count in-system queries.
        outstanding: List[List[float]] = [[] for _ in self.replicas]
        last_assign = [-1] * len(self.replicas)
        # -- fault tolerance (repro.faults; docs/FAULTS.md) ------------------
        tracker = (HealthTracker(len(runners), **self.health_kwargs)
                   if self.fault_aware else None)
        retry = self.retries
        hedge_after = self.hedge_after
        if tracker is not None:
            # The cluster owns retries (routing across replicas), but
            # the runners still report the fault counters the cluster
            # stamps into them on every telemetry flush.
            for runner in runners:
                runner._fault_aware = True
        # Shed queries keep the sentinel -1 (admission control); the
        # per-arrival ledger is exactly what streaming mode must not
        # materialize.
        if streaming:
            assignments = local_indices = None
        else:
            assignments = np.full(num_queries, -1, dtype=int)
            local_indices = np.full(num_queries, -1, dtype=int)

        shed_check = (adm is not None
                      and not getattr(adm, "admits_all", False))
        observe = getattr(adm, "observe", None) if adm is not None else None
        if adm is not None:
            adm.reset()
        scaler = self.autoscaler
        if scaler is not None:
            scaler.reset()
        shed_arrivals: List[float] = []
        # Fleet-level per-tier shed accounting (replicas never shed —
        # admission happens here, before the runner sees the query).
        shed_tier_counts = (np.zeros(len(tier_plan.tiers), dtype=np.int64)
                            if tier_plan is not None else None)
        shed_value = 0.0
        active_timeline: List[Tuple[int, Tuple[int, ...]]] = []
        cur_active: Optional[List[int]] = None
        active_sum = 0.0
        num_active = len(runners)
        interval = (sink_interval if sink_interval is not None
                    else DEFAULT_SINK_INTERVAL)

        # Fleet rebatching (max_batch > 1): same-replica routing streaks
        # buffer here and flush through step_many as one formed backlog.
        pend: List[float] = []         # buffered arrival times
        pend_q: List[int] = []         # their fleet indices
        pend_r = -1                    # replica the buffer belongs to

        def flush_pending() -> None:
            nonlocal pend_r
            if not pend:
                return
            if tracker is not None:
                flush_faulty()
                return
            runner = runners[pend_r]
            s_before = runner.num_served
            for completion in runner.step_many(pend):
                heapq.heappush(outstanding[pend_r], completion)
            if observe is not None:
                for s in range(s_before, runner.num_served):
                    observe(float(runner.queue_delay[s]),
                            float(runner.service_lat[s]))
            pend.clear()
            pend_q.clear()
            pend_r = -1

        def est_service(v: ReplicaView) -> float:
            est = v.est_latency
            return est if est == est else 0.0

        def assign(i: int, r: int, arrival: Optional[float]) -> None:
            hook = self.replicas[r].on_assign
            if hook is not None:
                hook(i, runners[r].total_served, arrival)
            if tier_plan is not None:
                runners[r].stamp_tier(runners[r].total_served,
                                      tier_plan, i)
            last_assign[r] = i

        def fleet_views(at: float) -> List[ReplicaView]:
            """Fresh fleet-wide views for batch-retry routing (the
            buffered batch was admitted long before the flush, so
            retries route over the whole fleet like serve_one's)."""
            return [ReplicaView(ridx, runner, len(outstanding[ridx]), at,
                                pool=self.replicas[ridx].pool)
                    for ridx, runner in enumerate(runners)]

        def flush_batch(r: int, batch: List[Tuple[int, float]],
                        floor: Optional[float]):
            """Flush ``batch`` of ``(fleet_q, arrival)`` through replica
            ``r``'s vectorized path.  Every member is (re-)stamped into
            its local slot first — retries shift slots, and the keyed
            backend hooks must agree with where the rows land.  Returns
            ``(completions, err)``: the in-order completion times of the
            prefix that executed, and the failing dispatch's error
            (``None`` = the whole batch completed)."""
            runner = runners[r]
            if floor is not None and floor > runner.free_at:
                # Backoff hold: the arrivals are already in the past, so
                # holding the admission head delays every retried start
                # exactly like step(not_before=...) would.
                runner.free_at = floor
            base = runner.total_served
            for off, (fq, a) in enumerate(batch):
                hook = self.replicas[r].on_assign
                if hook is not None:
                    hook(fq, base + off, a)
                if tier_plan is not None:
                    runner.stamp_tier(base + off, tier_plan, fq)
            rewarm(r, max(batch[0][1], floor or 0.0, runner.free_at))
            s_before = runner.num_served
            err = None
            try:
                comps = runner.step_many([a for (_, a) in batch])
            except TransientQueryError as e:
                err = e
                comps = list(getattr(e, "partial_completions", []))
            for c in comps:
                heapq.heappush(outstanding[r], c)
            if observe is not None:
                for s in range(s_before, runner.num_served):
                    observe(float(runner.queue_delay[s]),
                            float(runner.service_lat[s]))
            if not streaming:
                for off, (fq, _a) in enumerate(batch[:len(comps)]):
                    assignments[fq] = r
                    local_indices[fq] = base + off
            return comps, err

        def finalize_single(fq: int, comp: Optional[float],
                            win: int) -> None:
            """Ledger bookkeeping for one batch member that finished
            (or exhausted its budget) on the single-query path."""
            if comp is None:
                if not streaming:
                    assignments[fq] = -2
                    local_indices[fq] = -1
                return
            heapq.heappush(outstanding[win], comp)
            if not streaming:
                assignments[fq] = win
                local_indices[fq] = runners[win].num_served - 1
            if observe is not None:
                s = runners[win].num_served - 1
                observe(float(runners[win].queue_delay[s]),
                        float(runners[win].service_lat[s]))

        def retry_as_single(fq: int, arrival: float, r: int,
                            fail_t: float):
            """Continue a batch member's retry loop as a single after
            its first failure (mirrors serve_one's failure branch:
            backoff, healthy re-route, per-query budget)."""
            if retry.max_retries < 1:
                runners[r].num_failed += 1
                return None, r
            runners[r].num_retried += 1
            hold = fail_t + retry.delay(fq, 0)
            cand = fleet_views(hold)
            pool = [v for v in cand if tracker.healthy(v.index, hold)]
            if not pool:
                if self.when_all_unhealthy == "shed":
                    runners[r].num_failed += 1
                    return None, r
                hold = max(hold, min(tracker.ready_at(v.index)
                                     for v in cand))
                pool = [v for v in cand
                        if tracker.healthy(v.index, hold)]
            nxt = min(pool, key=lambda v: (max(v.free_at, hold), v.index))
            if nxt.index != r:
                r = nxt.index
                assign(fq, r, arrival)
            return serve_one(fq, r, arrival, hold, cand, attempt=1)

        def flush_faulty() -> None:
            """Fault-aware flush of the rebatch buffer: failures are
            attributed to single queries (fault-window chunks are
            single-query by construction) and handled per
            ``RetrySpec.batch_policy`` (docs/FAULTS.md)."""
            nonlocal pend_r
            policy = retry.batch_policy
            r = pend_r
            batch = list(zip(pend_q, pend))
            pend.clear()
            pend_q.clear()
            pend_r = -1
            # Whole-dispatch hedging (docs/FAULTS.md "Hedged batched
            # dispatch"): when the buffered dispatch's predicted wait
            # exceeds ``hedge_after``, duplicate the *whole* batch on
            # the least-loaded healthy peer; the predicted-faster copy
            # executes (first one wins) and the loser is charged the
            # dispatch's span as wasted work — the batched analogue of
            # serve_one's per-query hedge.
            hedge_loser = None
            if (hedge_after is not None and batch
                    and runners[r].free_at - batch[0][1] > hedge_after):
                t0 = batch[0][1]
                cand = fleet_views(t0)
                others = [v for v in cand
                          if v.index != r and tracker.healthy(v.index, t0)]
                if others:
                    vr = next(v for v in cand if v.index == r)
                    alt = min(others, key=lambda v: (max(v.free_at, t0),
                                                     v.index))
                    prim_eta = max(vr.free_at, t0) + est_service(vr)
                    alt_eta = max(alt.free_at, t0) + est_service(alt)
                    if alt_eta < prim_eta:
                        hedge_loser, r = r, alt.index
                    else:
                        hedge_loser = alt.index
            attempt = 0                      # shared budget ("all")
            floor: Optional[float] = None
            while batch:
                t0 = batch[0][1]
                comps, err = flush_batch(r, batch, floor)
                if hedge_loser is not None:
                    if err is None and comps:
                        # The loser would have held its head from its
                        # own start until the winner's drain — charge
                        # that span as wasted (cancelled) occupancy.
                        loser_start = max(runners[hedge_loser].free_at,
                                          t0)
                        charge = max(0.0, comps[-1] - loser_start)
                        if charge > 0.0:
                            runners[hedge_loser].charge_occupancy(t0,
                                                                  charge)
                        runners[r].num_hedged += len(comps)
                    # Hedge abandoned on failure, like serve_one's.
                    hedge_loser = None
                batch = batch[len(comps):]
                if err is None:
                    return
                fq, arrival = batch[0]
                fail_t = max(runners[r].free_at, arrival, floor or 0.0)
                tmo = getattr(err, "timeout", None)
                if tmo is not None and tmo == tmo:
                    runners[r].charge_occupancy(max(fail_t, arrival),
                                                float(tmo))
                    fail_t = runners[r].free_at
                tracker.record_failure(r, fail_t,
                                       until=getattr(err, "until",
                                                     math.nan))
                if policy == "all":
                    # Fail-whole-batch: the failing query and the tail
                    # re-flush together under one attempt budget.
                    if attempt >= retry.max_retries:
                        runners[r].num_failed += len(batch)
                        if not streaming:
                            for fq2, _a in batch:
                                assignments[fq2] = -2
                                local_indices[fq2] = -1
                        return
                    runners[r].num_retried += len(batch)
                    hold = fail_t + retry.delay(fq, attempt)
                    attempt += 1
                    cand = fleet_views(hold)
                    pool = [v for v in cand
                            if tracker.healthy(v.index, hold)]
                    if not pool:
                        if self.when_all_unhealthy == "shed":
                            runners[r].num_failed += len(batch)
                            if not streaming:
                                for fq2, _a in batch:
                                    assignments[fq2] = -2
                                    local_indices[fq2] = -1
                            return
                        hold = max(hold, min(tracker.ready_at(v.index)
                                             for v in cand))
                        pool = [v for v in cand
                                if tracker.healthy(v.index, hold)]
                    r = min(pool, key=lambda v: (max(v.free_at, hold),
                                                 v.index)).index
                    floor = hold
                    continue
                comp, win = retry_as_single(fq, arrival, r, fail_t)
                finalize_single(fq, comp, win)
                batch = batch[1:]
                floor = None
                if policy == "subset":
                    # Only the failing query left the batch; the
                    # untouched tail re-flushes as a batch.
                    continue
                # "resplit": the batch dissolves into singles.
                for fq2, a2 in batch:
                    comp, win = serve_one(fq2, r, a2, None,
                                          fleet_views(a2))
                    finalize_single(fq2, comp, win)
                return

        def rewarm(r: int, clock: float) -> None:
            """Fire the replica's re-warm hook once per open->probe
            transition, before its probe dispatch (off the timed path)."""
            if tracker.take_rewarm(r):
                hook = self.replicas[r].on_recover
                if hook is not None:
                    hook(clock)

        def serve_one(i: int, r: int, arrival: Optional[float],
                      not_before: Optional[float], candidates,
                      attempt: int = 0):
            """Serve fleet query ``i`` starting on replica ``r``,
            retrying transient failures across healthy replicas under
            the retry budget (exponential backoff, least-loaded
            re-route).  Returns ``(completion, winner)`` on success,
            ``(None, r)`` when the budget is exhausted.  ``candidates``
            is the active view list retries/hedges may route over;
            ``attempt`` pre-spends budget a batch member's first
            failure already consumed (docs/FAULTS.md)."""
            hedge_loser = None
            # Tail-latency hedging: when the chosen replica's backlog
            # exceeds ``hedge_after``, duplicate the dispatch on the
            # least-loaded healthy peer; the predicted-faster copy
            # executes (first one wins), the loser is cancelled at the
            # winner's completion and charged as wasted work.
            if (hedge_after is not None and arrival is not None
                    and runners[r].free_at - arrival > hedge_after):
                others = [v for v in candidates
                          if v.index != r
                          and tracker.healthy(v.index, arrival)]
                if others:
                    vr = next(v for v in candidates if v.index == r)
                    alt = min(others, key=lambda v: (max(v.free_at, arrival),
                                                     v.index))
                    prim_eta = max(vr.free_at, arrival) + est_service(vr)
                    alt_eta = max(alt.free_at, arrival) + est_service(alt)
                    if alt_eta < prim_eta:
                        hedge_loser, r = r, alt.index
                        assign(i, r, arrival)
                    else:
                        hedge_loser = alt.index
                        assign(i, hedge_loser, arrival)
            while True:
                rewarm(r, max(arrival or 0.0, not_before or 0.0,
                              runners[r].free_at))
                try:
                    completion = runners[r].step(arrival,
                                                 not_before=not_before)
                except TransientQueryError as err:
                    hedge_loser = None       # hedge abandoned on failure
                    fail_t = max(runners[r].free_at, arrival or 0.0,
                                 not_before or 0.0)
                    tmo = getattr(err, "timeout", None)
                    if tmo is not None and tmo == tmo:
                        # A timed-out hang occupied the head for the
                        # full timeout before cancellation.
                        runners[r].charge_occupancy(
                            max(fail_t, arrival or 0.0), float(tmo))
                        fail_t = runners[r].free_at
                    tracker.record_failure(r, fail_t,
                                           until=getattr(err, "until",
                                                         math.nan))
                    if attempt >= retry.max_retries:
                        runners[r].num_failed += 1
                        return None, r
                    runners[r].num_retried += 1
                    hold = fail_t + retry.delay(i, attempt)
                    attempt += 1
                    pool = [v for v in candidates
                            if tracker.healthy(v.index, hold)]
                    if not pool:
                        if self.when_all_unhealthy == "shed":
                            runners[r].num_failed += 1
                            return None, r
                        hold = max(hold, min(tracker.ready_at(v.index)
                                             for v in candidates))
                        pool = [v for v in candidates
                                if tracker.healthy(v.index, hold)]
                    nxt = min(pool, key=lambda v: (max(v.free_at, hold),
                                                   v.index))
                    if nxt.index != r:
                        r = nxt.index
                        assign(i, r, arrival)
                    not_before = hold
                    continue
                tracker.record_success(r, completion)
                if hedge_loser is not None:
                    loser_start = max(runners[hedge_loser].free_at,
                                      arrival or 0.0)
                    charge = max(0.0, completion - loser_start)
                    if charge > 0.0:
                        runners[hedge_loser].charge_occupancy(arrival,
                                                              charge)
                    runners[r].num_hedged += 1
                return completion, r

        now = 0.0
        for i in range(num_queries):
            if metrics_sink is not None and i and i % interval == 0:
                metrics_sink.emit(_fleet_snapshot(runners, fleet_extra,
                                                  slo, num_active))
            if arrivals is not None:
                arrival: Optional[float] = float(arrivals[i])
                now = arrival
            else:
                arrival = None
                # The closed-loop decision clock advances with the
                # serving fleet: drained replicas (autoscaling) sit at
                # a stale free_at and must not hold it back — and
                # neither must a breaker-open replica (its head stops
                # advancing while it is down, docs/FAULTS.md).
                pool = list(cur_active if cur_active is not None
                            else range(len(runners)))
                if tracker is not None:
                    up = [r for r in pool if tracker.state(r) != OPEN]
                    pool = up or pool
                now = min(runners[r].free_at for r in pool)
            views = []
            for ridx, (runner, heap) in enumerate(zip(runners,
                                                      outstanding)):
                while heap and heap[0] <= now:
                    heapq.heappop(heap)
                since = (i - last_assign[ridx] if last_assign[ridx] >= 0
                         else float("inf"))
                # Buffered (not yet flushed) queries are in-system.
                in_system = len(heap) + (len(pend) if ridx == pend_r
                                         else 0)
                views.append(ReplicaView(ridx, runner, in_system, now,
                                         since_assign=since,
                                         pool=self.replicas[ridx].pool))
            if scaler is not None:
                active = sorted(set(int(r) for r in
                                    scaler.active(i, now, views)))
                if not active or not all(0 <= r < len(runners)
                                         for r in active):
                    raise ValueError(
                        f"autoscaler {self.autoscaler_name!r} returned "
                        f"active set {active} for a fleet of "
                        f"{len(runners)}")
                if active != cur_active:
                    cur_active = active
                    if not streaming:
                        # The change-point list is unbounded in the
                        # worst case; streaming keeps the running mean
                        # (active_sum) instead.
                        active_timeline.append((i, tuple(active)))
                routed_views = [views[r] for r in active]
            else:
                routed_views = views
            candidates = routed_views
            not_before: Optional[float] = None
            # QoS context (docs/QOS.md): the arrival's tier stamp, with
            # the relative deadline anchored at the decision clock so
            # deadline-aware routers compare etas against an absolute
            # time.  ``None`` whenever tiers are off — tier-aware
            # routers then fall through to their untier-ed behaviour.
            if tier_plan is not None:
                tid = int(tier_plan.tier_ids[i])
                rel_dl = float(tier_plan.deadlines[i])
                tval = float(tier_plan.values[i])
                tier_ctx = QosRequest(query=i, tier=tid,
                                      priority=int(
                                          tier_plan.priorities[i]),
                                      deadline=now + rel_dl, value=tval)
            else:
                tier_ctx = None
            if tracker is not None:
                # Health-aware routing: the router only sees replicas
                # whose breaker admits traffic at ``now``.
                healthy = [v for v in routed_views
                           if tracker.healthy(v.index, now)]
                if not healthy:
                    if self.when_all_unhealthy == "shed":
                        if fleet_extra is not None:
                            if tier_ctx is not None:
                                fleet_extra.observe_shed(now, tier=tid,
                                                         value=tval)
                            else:
                                fleet_extra.observe_shed(now)
                        if not streaming:
                            shed_arrivals.append(now)
                        if tier_ctx is not None:
                            shed_tier_counts[tid] += 1
                            shed_value += tval
                        continue
                    # "wait": hold the dispatch until the earliest
                    # breaker expiry — that replica then admits a
                    # half-open probe, so the wait always terminates.
                    floor = min(tracker.ready_at(v.index)
                                for v in routed_views)
                    not_before = floor
                    healthy = [v for v in routed_views
                               if tracker.healthy(v.index, floor)]
                routed_views = healthy
            active_sum += len(routed_views)
            num_active = len(routed_views)
            if wants_request:
                pos = int(self.router.route(i, now, routed_views,
                                            request=tier_ctx))
            else:
                pos = int(self.router.route(i, now, routed_views))
            if not 0 <= pos < len(routed_views):
                raise ValueError(f"router {self.router_name!r} returned "
                                 f"position {pos} for "
                                 f"{len(routed_views)} active replicas")
            r = routed_views[pos].index
            if pend and r != pend_r:
                # The streak broke: flush the previous replica's
                # buffered backlog before this query is considered.
                flush_pending()
            if shed_check:
                # Fleet-level shedding sees the *routed* replica: the
                # router already picked the cheapest dispatch, so if
                # that one cannot meet the SLO, nobody can.
                v = views[r]
                if tier_ctx is not None:
                    view = AdmissionView(
                        query=i, arrival=arrival,
                        wait=0.0 if arrival is None else v.backlog,
                        est_service=v.est_bottleneck,
                        est_latency=v.est_latency,
                        tier=tid, priority=tier_ctx.priority,
                        deadline=rel_dl, value=tval)
                else:
                    view = AdmissionView(
                        query=i, arrival=arrival,
                        wait=0.0 if arrival is None else v.backlog,
                        est_service=v.est_bottleneck,
                        est_latency=v.est_latency)
                if not adm.admit(view):
                    if fleet_extra is not None:
                        if tier_ctx is not None:
                            fleet_extra.observe_shed(now, tier=tid,
                                                     value=tval)
                        else:
                            fleet_extra.observe_shed(now)
                    if not streaming:
                        shed_arrivals.append(now)
                    if tier_ctx is not None:
                        shed_tier_counts[tid] += 1
                        shed_value += tval
                    continue
            # total_served == num_served in dense mode; in streaming it
            # keeps counting across the runner's array recycling, so
            # backends see a stable local query index either way.
            # Buffered queries haven't stepped yet but already own their
            # local slots.
            local = runners[r].total_served + (len(pend) if r == pend_r
                                               else 0)
            hook = self.replicas[r].on_assign
            if hook is not None:
                hook(i, local, arrival)
            if tier_plan is not None:
                runners[r].stamp_tier(local, tier_plan, i)
            last_assign[r] = i
            if not streaming:
                assignments[i] = r
                local_indices[i] = local
            if self.max_batch > 1 and arrival is not None:
                pend.append(float(arrival))
                pend_q.append(i)
                pend_r = r
                if len(pend) >= self.max_batch:
                    flush_pending()
                continue
            if tracker is None:
                completion = runners[r].step(arrival)
            else:
                # Floor every dispatch at the fleet decision clock: a
                # recovering replica's head is stale (it served nothing
                # while down), and its probe must not start in the past.
                nb = now if not_before is None else max(not_before, now)
                completion, r = serve_one(i, r, arrival, nb, candidates)
                if completion is None:
                    # Retry budget exhausted: the query was admitted
                    # but never completed (sentinel -2 in the dense
                    # assignment ledger).
                    if not streaming:
                        assignments[i] = -2
                        local_indices[i] = -1
                    continue
                if not streaming:
                    # Retries/hedging may have re-routed the query.
                    assignments[i] = r
                    local_indices[i] = runners[r].num_served - 1
            heapq.heappush(outstanding[r], completion)
            if observe is not None:
                # The row the step just wrote: num_served - 1 (== local
                # in dense mode; streaming recycles indices, times don't
                # move).
                s = runners[r].num_served - 1
                observe(float(runners[r].queue_delay[s]),
                        float(runners[r].service_lat[s]))

        flush_pending()
        traces = [
            runner.finish(
                scheduler_name=(rep.name or scheduler_name),
                workload_name=wl_name,
                peak_throughput=rep.peak_throughput)
            for rep, runner in zip(self.replicas, runners)]
        if tracker is not None:
            # Per-replica unavailability: the larger of the fault
            # plan's crash windows (stamped by runner.finish) and the
            # breaker's observed open time — the two views of the same
            # outage, never summed (that would double-count).  The
            # breaker lives on the routing decision clock, so outages
            # still open close out at the clock's final reading, not at
            # the (possibly much later) backlog drain.
            breaker_down = tracker.finalize(now)
            for k, t in enumerate(traces):
                if streaming:
                    t.collector.downtime = max(t.collector.downtime,
                                               breaker_down[k])
                else:
                    t.downtime = max(t.downtime, breaker_down[k])
        # Downgrade accounting (docs/QOS.md): per-run delta of the
        # router's counters (the router object persists across serving
        # windows), threaded into whichever trace surface is active.
        downgrade_tier_counts = None
        if tier_plan is not None:
            dg_after = getattr(self.router, "downgrade_counts", None)
            if dg_after is not None:
                downgrade_tier_counts = np.zeros(len(tier_plan.tiers),
                                                 dtype=np.int64)
                for t, c in dg_after.items():
                    delta = int(c) - int(dg_before.get(t, 0))
                    if delta:
                        downgrade_tier_counts[int(t)] += delta
                if fleet_extra is not None:
                    fleet_extra.track_downgrades = True
                    for t in range(len(tier_plan.tiers)):
                        if downgrade_tier_counts[t]:
                            fleet_extra.note_downgrade(
                                t, int(downgrade_tier_counts[t]))
        if metrics_sink is not None:
            metrics_sink.emit(_fleet_snapshot(runners, fleet_extra, slo,
                                              num_active))
        if streaming:
            return StreamingClusterTrace(
                router=self.router_name, workload=wl_name,
                scheduler=scheduler_name, replicas=traces,
                num_queries=num_queries,
                admission=self.admission_name,
                autoscaler=self.autoscaler_name,
                slo_latency=slo, shed_collector=fleet_extra,
                active_sum=active_sum)
        return ClusterTrace(router=self.router_name, workload=wl_name,
                            scheduler=scheduler_name, replicas=traces,
                            assignments=assignments,
                            local_indices=local_indices,
                            admission=self.admission_name,
                            autoscaler=self.autoscaler_name,
                            slo_latency=slo,
                            shed_arrivals=np.asarray(shed_arrivals,
                                                     dtype=float),
                            active_timeline=active_timeline,
                            tier_names=(tier_plan.names
                                        if tier_plan is not None
                                        else None),
                            shed_tier_counts=shed_tier_counts,
                            shed_value=shed_value,
                            downgrade_tier_counts=downgrade_tier_counts)


def _run_cluster_impl(replicas: Sequence[Replica],
                num_queries: int,
                workload: Union[str, Workload, None] = "closed",
                workload_kwargs: Optional[dict] = None,
                router: Union[str, Router, None] = "round_robin",
                router_kwargs: Optional[dict] = None,
                scheduler_name: str = "",
                admission: Union[str, object, None] = None,
                admission_kwargs: Optional[dict] = None,
                autoscaler: Union[str, object, None] = None,
                autoscaler_kwargs: Optional[dict] = None,
                max_batch: int = 1,
                trace_mode: str = "dense",
                metrics_sink=None,
                sink_interval: Optional[int] = None,
                retries: Union[RetrySpec, int, dict, None] = None,
                hedge_after: Optional[float] = None,
                health_kwargs: Optional[dict] = None,
                when_all_unhealthy: str = "wait",
                tiers=None,
                tiers_kwargs: Optional[dict] = None
                ) -> Union[ClusterTrace, StreamingClusterTrace]:
    """Functional driver: build a :class:`Cluster` and serve one window."""
    cluster = Cluster(replicas, router=router, router_kwargs=router_kwargs,
                      admission=admission,
                      admission_kwargs=admission_kwargs,
                      autoscaler=autoscaler,
                      autoscaler_kwargs=autoscaler_kwargs,
                      max_batch=max_batch,
                      retries=retries, hedge_after=hedge_after,
                      health_kwargs=health_kwargs,
                      when_all_unhealthy=when_all_unhealthy,
                      tiers=tiers, tiers_kwargs=tiers_kwargs)
    return cluster.run(num_queries, workload=workload,
                       workload_kwargs=workload_kwargs,
                       scheduler_name=scheduler_name,
                       trace_mode=trace_mode, metrics_sink=metrics_sink,
                       sink_interval=sink_interval)


def run_cluster(replicas: Sequence[Replica],
                num_queries: int,
                workload: Union[str, Workload, None] = "closed",
                workload_kwargs: Optional[dict] = None,
                router: Union[str, Router, None] = "round_robin",
                router_kwargs: Optional[dict] = None,
                scheduler_name: str = "",
                admission: Union[str, object, None] = None,
                admission_kwargs: Optional[dict] = None,
                autoscaler: Union[str, object, None] = None,
                autoscaler_kwargs: Optional[dict] = None,
                max_batch: int = 1,
                trace_mode: str = "dense",
                metrics_sink=None,
                sink_interval: Optional[int] = None,
                retries: Union[RetrySpec, int, dict, None] = None,
                hedge_after: Optional[float] = None,
                health_kwargs: Optional[dict] = None,
                when_all_unhealthy: str = "wait",
                tiers=None,
                tiers_kwargs: Optional[dict] = None
                ) -> Union[ClusterTrace, StreamingClusterTrace]:
    """Serve one fleet window over pre-built :class:`Replica`\\ s.

    Thin wrapper over the unified :class:`repro.api.RunSpec` path (one
    declaration, one dispatcher — docs/API.md); the kwargs here map
    1:1 onto spec fields and new options land on the spec instead of
    this signature.  See :func:`_run_cluster_impl` for the full
    kwarg-level documentation.
    """
    from repro import api
    spec = api.RunSpec(
        replicas=replicas, num_queries=num_queries,
        scheduler=api.SchedulerSpec(name=(scheduler_name or "")),
        workload=api.WorkloadSpec(name=workload, kwargs=workload_kwargs),
        admission=api.AdmissionSpec(name=admission,
                                    kwargs=admission_kwargs),
        faults=api.FaultsSpec(hedge_after=hedge_after,
                              health_kwargs=health_kwargs,
                              when_all_unhealthy=when_all_unhealthy),
        retries=api.RetriesSpec(policy=retries),
        tiers=api.TiersSpec(spec=tiers, kwargs=tiers_kwargs),
        telemetry=api.TelemetrySpec(trace_mode=trace_mode,
                                    metrics_sink=metrics_sink,
                                    sink_interval=sink_interval),
        cluster=api.ClusterSpec(num_replicas=len(replicas),
                                router=router,
                                router_kwargs=router_kwargs,
                                autoscaler=autoscaler,
                                autoscaler_kwargs=autoscaler_kwargs,
                                max_batch=max_batch))
    return api.run(spec)
