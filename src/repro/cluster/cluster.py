"""The fleet driver: N pipeline replicas behind one routed arrival queue.

A :class:`Cluster` owns one :class:`Replica` per pipeline — each with
its *own* :class:`~repro.schedulers.runtime.RebalanceRuntime` (detector
state, exploration phases), its own executor (interference timeline /
slowdown schedule) and its own admission ledger — plus one
:class:`~repro.cluster.base.Router`.  :meth:`Cluster.run` (or the
functional :func:`run_cluster`) drives the shared arrival queue: the
workload generates *fleet* arrivals, the router picks a replica per
arrival, and the query is served through that replica's
:class:`~repro.workloads.runner.PipelineRunner` — the same event-loop
code ``run_pipeline`` drives for a single pipeline, fed one query at a
time so routing decisions always see up-to-date replica state.

Closed-loop semantics generalize per replica: a query dispatched to
replica ``r`` arrives the instant ``r`` can take it, and the router's
notion of "now" is the earliest admission-head free time across the
fleet — with ``n = 1`` this reduces *bit-identically* to the
single-pipeline closed loop (tests/test_cluster.py).
"""
from __future__ import annotations

import dataclasses
import heapq
from typing import Callable, List, Optional, Sequence, Tuple, Union

import numpy as np

from repro.cluster.base import ReplicaView, Router
from repro.cluster.registry import resolve_router
from repro.cluster.trace import ClusterTrace
from repro.control.base import AdmissionView
from repro.control.registry import resolve_admission, resolve_autoscaler
from repro.schedulers.runtime import RebalanceRuntime
from repro.telemetry.streaming import (
    DEFAULT_SINK_INTERVAL,
    StreamingClusterTrace,
    StreamingCollector,
)
from repro.workloads.base import QueryExecutor, Workload
from repro.workloads.runner import PipelineRunner, resolve_arrivals


def _fleet_snapshot(runners, extra: Optional[StreamingCollector],
                    slo: float, num_active: int) -> dict:
    """Aggregate per-replica collectors into one fleet snapshot for the
    sink: counters sum exactly, sketches merge within tolerance."""
    agg = StreamingCollector(slo=slo)
    for runner in runners:
        runner.flush_telemetry()
        agg.absorb(runner.telemetry)
    if extra is not None:
        agg.absorb(extra)
    reg = agg.registry
    reg.gauge("num_replicas", "fleet size").set(len(runners))
    reg.gauge("active_replicas",
              "replicas in the routed set").set(num_active)
    return reg.snapshot()


@dataclasses.dataclass
class Replica:
    """One pipeline behind the router.

    ``on_assign(fleet_q, local_q, arrival)`` — optional backend hook
    invoked when a fleet query is routed here, *before* it executes:
    the live backend appends the query's token array to the replica's
    local stream, the time-indexed simulator backend appends the
    arrival time to the replica's clock (``arrival`` is ``None`` for a
    closed loop).  ``peak_throughput`` is the replica's
    interference-free reference for SLO accounting (NaN = unknown; the
    live backend stamps it post-run).
    """

    executor: QueryExecutor
    runtime: RebalanceRuntime
    name: str = ""
    peak_throughput: float = float("nan")
    on_assign: Optional[Callable[[int, int, Optional[float]], None]] = None


class Cluster:
    """N replicas + one router; reusable across serving windows.

    The SLO control plane (``repro.control``, docs/CONTROL.md) hooks in
    at the fleet level: an ``admission`` policy may shed an arrival
    *after* routing (the decision sees the chosen replica's predicted
    wait and service estimate — if the best replica cannot meet the
    SLO, nobody can), and an ``autoscaler`` decides per arrival which
    replicas are active — the router only ever sees the active subset,
    so a drained replica simply stops receiving work and finishes its
    backlog.  Defaults (no admission policy, no autoscaler) leave the
    fleet loop bit-identical to the pre-control-plane cluster.

    ``max_batch > 1`` opts into fleet rebatching: consecutive open-loop
    arrivals routed to the *same* replica are buffered and flushed
    through that replica's :meth:`~repro.workloads.runner.PipelineRunner.
    step_many` — the routed backlog re-forms into batches (one set of
    stage dispatches per streak) instead of executing query-by-query.
    Buffered queries count in every :class:`ReplicaView`'s
    ``outstanding`` so routing stays load-aware, but ledger-derived
    estimates (``backlog``, ``free_at``) trail the unflushed tail by up
    to ``max_batch - 1`` queries.  The default ``max_batch = 1`` is the
    exact pre-rebatching path (every query steps immediately), and a
    closed loop never buffers — its decision clock needs each query's
    completion.

    Note: ``adaptive_batch`` has no effect at the fleet level — the
    rebatch streak length is capped by routing locality and
    ``max_batch``, not by a steered bound.
    """

    def __init__(self, replicas: Sequence[Replica],
                 router: Union[str, Router, None] = "round_robin",
                 router_kwargs: Optional[dict] = None,
                 admission: Union[str, object, None] = None,
                 admission_kwargs: Optional[dict] = None,
                 autoscaler: Union[str, object, None] = None,
                 autoscaler_kwargs: Optional[dict] = None,
                 max_batch: int = 1):
        if len(replicas) < 1:
            raise ValueError("a cluster needs at least one replica")
        self.replicas = list(replicas)
        self.max_batch = max(1, int(max_batch))
        self.router = resolve_router(router, router_kwargs)
        self.router_name = getattr(self.router, "name",
                                   type(self.router).__name__)
        self.admission = resolve_admission(admission, admission_kwargs)
        self.admission_name = ("none" if self.admission is None
                               else getattr(self.admission, "name",
                                            type(self.admission).__name__))
        # None = autoscaling disabled (all replicas always active) —
        # same behaviour as the "static" built-in, without threading a
        # policy object through the fleet loop at all.
        if autoscaler is None and autoscaler_kwargs:
            raise ValueError("autoscaler_kwargs given but no autoscaler "
                             "selected")
        self.autoscaler = (None if autoscaler is None
                           else resolve_autoscaler(autoscaler,
                                                   autoscaler_kwargs))
        self.autoscaler_name = ("static" if self.autoscaler is None
                                else getattr(self.autoscaler, "name",
                                             type(self.autoscaler).__name__))

    def run(self, num_queries: int,
            workload: Union[str, Workload, None] = "closed",
            workload_kwargs: Optional[dict] = None,
            scheduler_name: str = "",
            trace_mode: str = "dense",
            metrics_sink=None,
            sink_interval: Optional[int] = None
            ) -> Union[ClusterTrace, StreamingClusterTrace]:
        """Serve ``num_queries`` fleet arrivals of ``workload`` through
        the routed replicas; returns a :class:`ClusterTrace`.

        Per arrival: pop completed work from each replica's
        outstanding ledger, build the :class:`ReplicaView` snapshots,
        ask the router, fire the backend's ``on_assign`` hook, and
        serve the query through the chosen replica's runner (advancing
        its environment, polling its scheduler runtime, accounting its
        arrival queue — identical per-query semantics to
        ``run_pipeline``).

        ``trace_mode="streaming"`` (docs/TELEMETRY.md) runs every
        replica at flat memory and returns a
        :class:`~repro.telemetry.StreamingClusterTrace` — same
        ``summary()`` keys, fleet percentiles from merged per-replica
        sketches.  ``metrics_sink`` receives fleet-aggregated snapshots
        every ``sink_interval`` arrivals in either mode.
        """
        if trace_mode not in ("dense", "streaming"):
            raise ValueError(f"unknown trace_mode {trace_mode!r}; "
                             f"expected 'dense' or 'streaming'")
        streaming = trace_mode == "streaming"
        wl_name, arrivals = resolve_arrivals(workload, workload_kwargs,
                                             num_queries)

        adm = self.admission
        slo = float(getattr(adm, "slo", float("inf"))
                    if adm is not None else float("inf"))
        use_telemetry = streaming or metrics_sink is not None
        # Fleet-level sheds never reach a replica, so they get their
        # own collector (merged into the fleet view at read time).
        fleet_extra = StreamingCollector(slo=slo) if use_telemetry else None

        # Pre-size each runner at its balanced share; a skewed router
        # just grows that replica's arrays (doubling) as it serves —
        # streaming runners stay at their fixed recycling capacity.
        share = -(-num_queries // len(self.replicas))
        runners = [PipelineRunner(rep.executor, rep.runtime, share,
                                  trace_mode=trace_mode,
                                  telemetry=(StreamingCollector(slo=slo)
                                             if use_telemetry else None))
                   for rep in self.replicas]
        # Outstanding completions per replica: popped against the
        # (monotone) decision clock to count in-system queries.
        outstanding: List[List[float]] = [[] for _ in self.replicas]
        last_assign = [-1] * len(self.replicas)
        # Shed queries keep the sentinel -1 (admission control); the
        # per-arrival ledger is exactly what streaming mode must not
        # materialize.
        if streaming:
            assignments = local_indices = None
        else:
            assignments = np.full(num_queries, -1, dtype=int)
            local_indices = np.full(num_queries, -1, dtype=int)

        shed_check = (adm is not None
                      and not getattr(adm, "admits_all", False))
        observe = getattr(adm, "observe", None) if adm is not None else None
        if adm is not None:
            adm.reset()
        scaler = self.autoscaler
        if scaler is not None:
            scaler.reset()
        shed_arrivals: List[float] = []
        active_timeline: List[Tuple[int, Tuple[int, ...]]] = []
        cur_active: Optional[List[int]] = None
        active_sum = 0.0
        num_active = len(runners)
        interval = (sink_interval if sink_interval is not None
                    else DEFAULT_SINK_INTERVAL)

        # Fleet rebatching (max_batch > 1): same-replica routing streaks
        # buffer here and flush through step_many as one formed backlog.
        pend: List[float] = []         # buffered arrival times
        pend_r = -1                    # replica the buffer belongs to

        def flush_pending() -> None:
            nonlocal pend_r
            if not pend:
                return
            runner = runners[pend_r]
            s_before = runner.num_served
            for completion in runner.step_many(pend):
                heapq.heappush(outstanding[pend_r], completion)
            if observe is not None:
                for s in range(s_before, runner.num_served):
                    observe(float(runner.queue_delay[s]),
                            float(runner.service_lat[s]))
            pend.clear()
            pend_r = -1

        for i in range(num_queries):
            if metrics_sink is not None and i and i % interval == 0:
                metrics_sink.emit(_fleet_snapshot(runners, fleet_extra,
                                                  slo, num_active))
            if arrivals is not None:
                arrival: Optional[float] = float(arrivals[i])
                now = arrival
            else:
                arrival = None
                # The closed-loop decision clock advances with the
                # serving fleet: drained replicas (autoscaling) sit at
                # a stale free_at and must not hold it back.
                now = min(runners[r].free_at
                          for r in (cur_active
                                    if cur_active is not None
                                    else range(len(runners))))
            views = []
            for ridx, (runner, heap) in enumerate(zip(runners,
                                                      outstanding)):
                while heap and heap[0] <= now:
                    heapq.heappop(heap)
                since = (i - last_assign[ridx] if last_assign[ridx] >= 0
                         else float("inf"))
                # Buffered (not yet flushed) queries are in-system.
                in_system = len(heap) + (len(pend) if ridx == pend_r
                                         else 0)
                views.append(ReplicaView(ridx, runner, in_system, now,
                                         since_assign=since))
            if scaler is not None:
                active = sorted(set(int(r) for r in
                                    scaler.active(i, now, views)))
                if not active or not all(0 <= r < len(runners)
                                         for r in active):
                    raise ValueError(
                        f"autoscaler {self.autoscaler_name!r} returned "
                        f"active set {active} for a fleet of "
                        f"{len(runners)}")
                if active != cur_active:
                    cur_active = active
                    if not streaming:
                        # The change-point list is unbounded in the
                        # worst case; streaming keeps the running mean
                        # (active_sum) instead.
                        active_timeline.append((i, tuple(active)))
                routed_views = [views[r] for r in active]
            else:
                routed_views = views
            active_sum += len(routed_views)
            num_active = len(routed_views)
            pos = int(self.router.route(i, now, routed_views))
            if not 0 <= pos < len(routed_views):
                raise ValueError(f"router {self.router_name!r} returned "
                                 f"position {pos} for "
                                 f"{len(routed_views)} active replicas")
            r = routed_views[pos].index
            if pend and r != pend_r:
                # The streak broke: flush the previous replica's
                # buffered backlog before this query is considered.
                flush_pending()
            if shed_check:
                # Fleet-level shedding sees the *routed* replica: the
                # router already picked the cheapest dispatch, so if
                # that one cannot meet the SLO, nobody can.
                v = views[r]
                view = AdmissionView(
                    query=i, arrival=arrival,
                    wait=0.0 if arrival is None else v.backlog,
                    est_service=v.est_bottleneck,
                    est_latency=v.est_latency)
                if not adm.admit(view):
                    if fleet_extra is not None:
                        fleet_extra.observe_shed(now)
                    if not streaming:
                        shed_arrivals.append(now)
                    continue
            # total_served == num_served in dense mode; in streaming it
            # keeps counting across the runner's array recycling, so
            # backends see a stable local query index either way.
            # Buffered queries haven't stepped yet but already own their
            # local slots.
            local = runners[r].total_served + (len(pend) if r == pend_r
                                               else 0)
            hook = self.replicas[r].on_assign
            if hook is not None:
                hook(i, local, arrival)
            last_assign[r] = i
            if not streaming:
                assignments[i] = r
                local_indices[i] = local
            if self.max_batch > 1 and arrival is not None:
                pend.append(float(arrival))
                pend_r = r
                if len(pend) >= self.max_batch:
                    flush_pending()
                continue
            completion = runners[r].step(arrival)
            heapq.heappush(outstanding[r], completion)
            if observe is not None:
                # The row the step just wrote: num_served - 1 (== local
                # in dense mode; streaming recycles indices, times don't
                # move).
                s = runners[r].num_served - 1
                observe(float(runners[r].queue_delay[s]),
                        float(runners[r].service_lat[s]))

        flush_pending()
        traces = [
            runner.finish(
                scheduler_name=(rep.name or scheduler_name),
                workload_name=wl_name,
                peak_throughput=rep.peak_throughput)
            for rep, runner in zip(self.replicas, runners)]
        if metrics_sink is not None:
            metrics_sink.emit(_fleet_snapshot(runners, fleet_extra, slo,
                                              num_active))
        if streaming:
            return StreamingClusterTrace(
                router=self.router_name, workload=wl_name,
                scheduler=scheduler_name, replicas=traces,
                num_queries=num_queries,
                admission=self.admission_name,
                autoscaler=self.autoscaler_name,
                slo_latency=slo, shed_collector=fleet_extra,
                active_sum=active_sum)
        return ClusterTrace(router=self.router_name, workload=wl_name,
                            scheduler=scheduler_name, replicas=traces,
                            assignments=assignments,
                            local_indices=local_indices,
                            admission=self.admission_name,
                            autoscaler=self.autoscaler_name,
                            slo_latency=slo,
                            shed_arrivals=np.asarray(shed_arrivals,
                                                     dtype=float),
                            active_timeline=active_timeline)


def run_cluster(replicas: Sequence[Replica],
                num_queries: int,
                workload: Union[str, Workload, None] = "closed",
                workload_kwargs: Optional[dict] = None,
                router: Union[str, Router, None] = "round_robin",
                router_kwargs: Optional[dict] = None,
                scheduler_name: str = "",
                admission: Union[str, object, None] = None,
                admission_kwargs: Optional[dict] = None,
                autoscaler: Union[str, object, None] = None,
                autoscaler_kwargs: Optional[dict] = None,
                max_batch: int = 1,
                trace_mode: str = "dense",
                metrics_sink=None,
                sink_interval: Optional[int] = None
                ) -> Union[ClusterTrace, StreamingClusterTrace]:
    """Functional driver: build a :class:`Cluster` and serve one window."""
    cluster = Cluster(replicas, router=router, router_kwargs=router_kwargs,
                      admission=admission,
                      admission_kwargs=admission_kwargs,
                      autoscaler=autoscaler,
                      autoscaler_kwargs=autoscaler_kwargs,
                      max_batch=max_batch)
    return cluster.run(num_queries, workload=workload,
                       workload_kwargs=workload_kwargs,
                       scheduler_name=scheduler_name,
                       trace_mode=trace_mode, metrics_sink=metrics_sink,
                       sink_interval=sink_interval)
