"""The fleet driver: N pipeline replicas behind one routed arrival queue.

A :class:`Cluster` owns one :class:`Replica` per pipeline — each with
its *own* :class:`~repro.schedulers.runtime.RebalanceRuntime` (detector
state, exploration phases), its own executor (interference timeline /
slowdown schedule) and its own admission ledger — plus one
:class:`~repro.cluster.base.Router`.  :meth:`Cluster.run` (or the
functional :func:`run_cluster`) drives the shared arrival queue: the
workload generates *fleet* arrivals, the router picks a replica per
arrival, and the query is served through that replica's
:class:`~repro.workloads.runner.PipelineRunner` — the same event-loop
code ``run_pipeline`` drives for a single pipeline, fed one query at a
time so routing decisions always see up-to-date replica state.

Closed-loop semantics generalize per replica: a query dispatched to
replica ``r`` arrives the instant ``r`` can take it, and the router's
notion of "now" is the earliest admission-head free time across the
fleet — with ``n = 1`` this reduces *bit-identically* to the
single-pipeline closed loop (tests/test_cluster.py).
"""
from __future__ import annotations

import dataclasses
import heapq
from typing import Callable, List, Optional, Sequence, Tuple, Union

import numpy as np

from repro.cluster.base import ReplicaView, Router
from repro.cluster.registry import resolve_router
from repro.cluster.trace import ClusterTrace
from repro.control.base import AdmissionView
from repro.control.registry import resolve_admission, resolve_autoscaler
from repro.schedulers.runtime import RebalanceRuntime
from repro.workloads.base import QueryExecutor, Workload
from repro.workloads.runner import PipelineRunner, resolve_arrivals


@dataclasses.dataclass
class Replica:
    """One pipeline behind the router.

    ``on_assign(fleet_q, local_q, arrival)`` — optional backend hook
    invoked when a fleet query is routed here, *before* it executes:
    the live backend appends the query's token array to the replica's
    local stream, the time-indexed simulator backend appends the
    arrival time to the replica's clock (``arrival`` is ``None`` for a
    closed loop).  ``peak_throughput`` is the replica's
    interference-free reference for SLO accounting (NaN = unknown; the
    live backend stamps it post-run).
    """

    executor: QueryExecutor
    runtime: RebalanceRuntime
    name: str = ""
    peak_throughput: float = float("nan")
    on_assign: Optional[Callable[[int, int, Optional[float]], None]] = None


class Cluster:
    """N replicas + one router; reusable across serving windows.

    The SLO control plane (``repro.control``, docs/CONTROL.md) hooks in
    at the fleet level: an ``admission`` policy may shed an arrival
    *after* routing (the decision sees the chosen replica's predicted
    wait and service estimate — if the best replica cannot meet the
    SLO, nobody can), and an ``autoscaler`` decides per arrival which
    replicas are active — the router only ever sees the active subset,
    so a drained replica simply stops receiving work and finishes its
    backlog.  Defaults (no admission policy, no autoscaler) leave the
    fleet loop bit-identical to the pre-control-plane cluster.

    Note: ``adaptive_batch`` has no effect at the fleet level — cluster
    replicas are driven one query per routing decision (the scalar
    tick), so there is no batch bound to steer; per-replica adaptive
    batching inside cluster runs is a ROADMAP follow-up.
    """

    def __init__(self, replicas: Sequence[Replica],
                 router: Union[str, Router, None] = "round_robin",
                 router_kwargs: Optional[dict] = None,
                 admission: Union[str, object, None] = None,
                 admission_kwargs: Optional[dict] = None,
                 autoscaler: Union[str, object, None] = None,
                 autoscaler_kwargs: Optional[dict] = None):
        if len(replicas) < 1:
            raise ValueError("a cluster needs at least one replica")
        self.replicas = list(replicas)
        self.router = resolve_router(router, router_kwargs)
        self.router_name = getattr(self.router, "name",
                                   type(self.router).__name__)
        self.admission = resolve_admission(admission, admission_kwargs)
        self.admission_name = ("none" if self.admission is None
                               else getattr(self.admission, "name",
                                            type(self.admission).__name__))
        # None = autoscaling disabled (all replicas always active) —
        # same behaviour as the "static" built-in, without threading a
        # policy object through the fleet loop at all.
        if autoscaler is None and autoscaler_kwargs:
            raise ValueError("autoscaler_kwargs given but no autoscaler "
                             "selected")
        self.autoscaler = (None if autoscaler is None
                           else resolve_autoscaler(autoscaler,
                                                   autoscaler_kwargs))
        self.autoscaler_name = ("static" if self.autoscaler is None
                                else getattr(self.autoscaler, "name",
                                             type(self.autoscaler).__name__))

    def run(self, num_queries: int,
            workload: Union[str, Workload, None] = "closed",
            workload_kwargs: Optional[dict] = None,
            scheduler_name: str = "") -> ClusterTrace:
        """Serve ``num_queries`` fleet arrivals of ``workload`` through
        the routed replicas; returns a :class:`ClusterTrace`.

        Per arrival: pop completed work from each replica's
        outstanding ledger, build the :class:`ReplicaView` snapshots,
        ask the router, fire the backend's ``on_assign`` hook, and
        serve the query through the chosen replica's runner (advancing
        its environment, polling its scheduler runtime, accounting its
        arrival queue — identical per-query semantics to
        ``run_pipeline``).
        """
        wl_name, arrivals = resolve_arrivals(workload, workload_kwargs,
                                             num_queries)

        # Pre-size each runner at its balanced share; a skewed router
        # just grows that replica's arrays (doubling) as it serves.
        share = -(-num_queries // len(self.replicas))
        runners = [PipelineRunner(rep.executor, rep.runtime, share)
                   for rep in self.replicas]
        # Outstanding completions per replica: popped against the
        # (monotone) decision clock to count in-system queries.
        outstanding: List[List[float]] = [[] for _ in self.replicas]
        last_assign = [-1] * len(self.replicas)
        # Shed queries keep the sentinel -1 (admission control).
        assignments = np.full(num_queries, -1, dtype=int)
        local_indices = np.full(num_queries, -1, dtype=int)

        adm = self.admission
        shed_check = (adm is not None
                      and not getattr(adm, "admits_all", False))
        observe = getattr(adm, "observe", None) if adm is not None else None
        if adm is not None:
            adm.reset()
        scaler = self.autoscaler
        if scaler is not None:
            scaler.reset()
        shed_arrivals: List[float] = []
        active_timeline: List[Tuple[int, Tuple[int, ...]]] = []
        cur_active: Optional[List[int]] = None

        for i in range(num_queries):
            if arrivals is not None:
                arrival: Optional[float] = float(arrivals[i])
                now = arrival
            else:
                arrival = None
                # The closed-loop decision clock advances with the
                # serving fleet: drained replicas (autoscaling) sit at
                # a stale free_at and must not hold it back.
                now = min(runners[r].free_at
                          for r in (cur_active
                                    if cur_active is not None
                                    else range(len(runners))))
            views = []
            for ridx, (runner, heap) in enumerate(zip(runners,
                                                      outstanding)):
                while heap and heap[0] <= now:
                    heapq.heappop(heap)
                since = (i - last_assign[ridx] if last_assign[ridx] >= 0
                         else float("inf"))
                views.append(ReplicaView(ridx, runner, len(heap), now,
                                         since_assign=since))
            if scaler is not None:
                active = sorted(set(int(r) for r in
                                    scaler.active(i, now, views)))
                if not active or not all(0 <= r < len(runners)
                                         for r in active):
                    raise ValueError(
                        f"autoscaler {self.autoscaler_name!r} returned "
                        f"active set {active} for a fleet of "
                        f"{len(runners)}")
                if active != cur_active:
                    cur_active = active
                    active_timeline.append((i, tuple(active)))
                routed_views = [views[r] for r in active]
            else:
                routed_views = views
            pos = int(self.router.route(i, now, routed_views))
            if not 0 <= pos < len(routed_views):
                raise ValueError(f"router {self.router_name!r} returned "
                                 f"position {pos} for "
                                 f"{len(routed_views)} active replicas")
            r = routed_views[pos].index
            if shed_check:
                # Fleet-level shedding sees the *routed* replica: the
                # router already picked the cheapest dispatch, so if
                # that one cannot meet the SLO, nobody can.
                v = views[r]
                view = AdmissionView(
                    query=i, arrival=arrival,
                    wait=0.0 if arrival is None else v.backlog,
                    est_service=v.est_bottleneck,
                    est_latency=v.est_latency)
                if not adm.admit(view):
                    shed_arrivals.append(now)
                    continue
            local = runners[r].num_served
            hook = self.replicas[r].on_assign
            if hook is not None:
                hook(i, local, arrival)
            completion = runners[r].step(arrival)
            heapq.heappush(outstanding[r], completion)
            last_assign[r] = i
            assignments[i] = r
            local_indices[i] = local
            if observe is not None:
                observe(float(runners[r].queue_delay[local]),
                        float(runners[r].service_lat[local]))

        traces = [
            runner.finish(
                scheduler_name=(rep.name or scheduler_name),
                workload_name=wl_name,
                peak_throughput=rep.peak_throughput)
            for rep, runner in zip(self.replicas, runners)]
        return ClusterTrace(router=self.router_name, workload=wl_name,
                            scheduler=scheduler_name, replicas=traces,
                            assignments=assignments,
                            local_indices=local_indices,
                            admission=self.admission_name,
                            autoscaler=self.autoscaler_name,
                            slo_latency=float(getattr(adm, "slo",
                                                      float("inf"))
                                              if adm is not None
                                              else float("inf")),
                            shed_arrivals=np.asarray(shed_arrivals,
                                                     dtype=float),
                            active_timeline=active_timeline)


def run_cluster(replicas: Sequence[Replica],
                num_queries: int,
                workload: Union[str, Workload, None] = "closed",
                workload_kwargs: Optional[dict] = None,
                router: Union[str, Router, None] = "round_robin",
                router_kwargs: Optional[dict] = None,
                scheduler_name: str = "",
                admission: Union[str, object, None] = None,
                admission_kwargs: Optional[dict] = None,
                autoscaler: Union[str, object, None] = None,
                autoscaler_kwargs: Optional[dict] = None) -> ClusterTrace:
    """Functional driver: build a :class:`Cluster` and serve one window."""
    cluster = Cluster(replicas, router=router, router_kwargs=router_kwargs,
                      admission=admission,
                      admission_kwargs=admission_kwargs,
                      autoscaler=autoscaler,
                      autoscaler_kwargs=autoscaler_kwargs)
    return cluster.run(num_queries, workload=workload,
                       workload_kwargs=workload_kwargs,
                       scheduler_name=scheduler_name)
