"""Fleet-level result type, built on the per-replica PipelineTrace.

A :class:`ClusterTrace` holds one
:class:`~repro.workloads.trace.PipelineTrace` per replica plus the
assignment ledger (which replica served each fleet arrival, in arrival
order).  Fleet metrics come from the :attr:`fleet` trace — the
per-replica arrays gathered back into fleet arrival order and run
through the *same* PipelineTrace metric code — so p50/p99, queueing
delay and offered/achieved load mean exactly what they mean for a
single pipeline.  Only the SLO reference differs: each query's
throughput is compared against *its own replica's* interference-free
peak (fleets may be heterogeneous).
"""
from __future__ import annotations

import dataclasses
from typing import Dict, List, Optional, Tuple

import numpy as np

from repro.workloads.trace import PipelineTrace


@dataclasses.dataclass
class ClusterTrace:
    router: str
    workload: str
    scheduler: str
    #: One finished trace per replica (local query order).
    replicas: List[PipelineTrace]
    #: Fleet arrival order -> replica index that served the query
    #: (``-1`` = shed by the admission policy, docs/CONTROL.md;
    #: ``-2`` = admitted but failed after exhausting its retry budget,
    #: docs/FAULTS.md — no per-query row exists for either).
    assignments: np.ndarray
    #: Fleet arrival order -> index within that replica's trace
    #: (``-1`` for shed queries).
    local_indices: np.ndarray
    # -- control plane (repro.control) ---------------------------------------
    #: Admission policy the fleet was served under.
    admission: str = "none"
    #: Autoscaler sizing the active replica set.
    autoscaler: str = "static"
    #: Latency objective the admission policy enforced (+inf = none).
    slo_latency: float = float("inf")
    #: Fleet arrival times of shed queries.
    shed_arrivals: Optional[np.ndarray] = None
    #: Change points of the active replica set: ``(fleet query index,
    #: active indices)`` — empty when no autoscaler ran (all active).
    active_timeline: Optional[List[Tuple[int, Tuple[int, ...]]]] = None
    # -- QoS tiers (repro.qos, docs/QOS.md) ----------------------------------
    #: Tier names in tier-id order (``None`` = run had no tiers).
    tier_names: Optional[List[str]] = None
    #: Fleet-level sheds per tier (replicas never shed; admission
    #: happens at the fleet layer before any runner sees the query).
    shed_tier_counts: Optional[np.ndarray] = None
    #: Total SLO value of the shed arrivals.
    shed_value: float = 0.0
    #: Per-tier downgrade counts (``downgrade`` router, heterogeneous
    #: fleets); ``None`` when the router keeps no downgrade ledger.
    downgrade_tier_counts: Optional[np.ndarray] = None

    def __post_init__(self):
        self.assignments = np.asarray(self.assignments, dtype=int)
        self.local_indices = np.asarray(self.local_indices, dtype=int)
        if self.shed_arrivals is None:
            self.shed_arrivals = np.empty(0)
        else:
            self.shed_arrivals = np.asarray(self.shed_arrivals, dtype=float)
        if self.active_timeline is None:
            self.active_timeline = []

    # -- shape ---------------------------------------------------------------
    @property
    def num_replicas(self) -> int:
        return len(self.replicas)

    @property
    def num_queries(self) -> int:
        """All offered fleet arrivals, admitted plus shed."""
        return len(self.assignments)

    @property
    def admitted_mask(self) -> np.ndarray:
        """True where the fleet arrival was admitted (served)."""
        return self.assignments >= 0

    @property
    def num_admitted(self) -> int:
        return int(np.count_nonzero(self.admitted_mask))

    @property
    def num_shed(self) -> int:
        return len(self.shed_arrivals)

    @property
    def shed_rate(self) -> float:
        """Fraction of offered fleet arrivals that were shed."""
        return self.num_shed / self.num_queries if self.num_queries else 0.0

    @property
    def active_counts(self) -> np.ndarray:
        """Active replicas at each fleet arrival (all, without an
        autoscaler) — the active-replica timeline as a dense array."""
        counts = np.full(self.num_queries, self.num_replicas, dtype=int)
        for start, active in self.active_timeline:
            counts[start:] = len(active)
        return counts

    @property
    def replica_counts(self) -> np.ndarray:
        """Queries served per replica."""
        return np.bincount(self.assignments[self.admitted_mask],
                           minlength=self.num_replicas)

    # -- fleet-order gathers --------------------------------------------------
    def gather(self, field: str) -> np.ndarray:
        """Per-replica per-query array ``field`` gathered into fleet
        arrival order over the *admitted* queries."""
        ref = getattr(self.replicas[0], field)
        out = np.empty(self.num_queries, dtype=np.asarray(ref).dtype)
        for r, t in enumerate(self.replicas):
            out[self.assignments == r] = getattr(t, field)
        return out[self.admitted_mask]

    @property
    def fleet(self) -> PipelineTrace:
        """The fleet as one PipelineTrace (computed on access so
        post-run stamping of replica peaks is picked up).

        ``peak_throughput`` is only meaningful for ``n = 1`` (where the
        fleet *is* the replica); multi-replica SLO accounting goes
        through :meth:`slo_violations`, which compares each query
        against its own replica's peak.
        """
        configs: List[Optional[list]] = [None] * self.num_queries
        for r, t in enumerate(self.replicas):
            for pos, cfg in zip(np.flatnonzero(self.assignments == r),
                                t.configs_trace):
                configs[pos] = cfg
        configs = [c for c, ok in zip(configs, self.admitted_mask) if ok]
        rc = None
        if all(t.rc_throughputs is not None for t in self.replicas):
            rc = self.gather("rc_throughputs")
        if self.num_replicas == 1:
            peak = self.replicas[0].peak_throughput
        else:
            # Served-share-weighted mean of the known per-replica peaks:
            # the interference-free rate the fleet's actual dispatch mix
            # would sustain.  A plain mean misreads heterogeneous fleets
            # (docs/QOS.md) — a small-model replica serving 5% of the
            # traffic must not drag the reference down as if it served
            # half.  NaN when no serving replica has a known peak.
            acc = w = 0.0
            for t, cnt in zip(self.replicas, self.replica_counts):
                if cnt and np.isfinite(t.peak_throughput):
                    acc += float(cnt) * t.peak_throughput
                    w += float(cnt)
            peak = acc / w if w else float("nan")
        tier_cols: Dict[str, object] = {}
        if self.tier_names is not None:
            tier_cols = dict(
                tier_names=list(self.tier_names),
                tier_ids=self.gather("tier_ids"),
                tier_deadlines=self.gather("tier_deadlines"),
                tier_values=self.gather("tier_values"),
                shed_tier_counts=self.shed_tier_counts,
                shed_value=self.shed_value,
                downgrade_tier_counts=self.downgrade_tier_counts,
            )
        return PipelineTrace(
            scheduler=self.scheduler,
            latencies=self.gather("latencies"),
            throughputs=self.gather("throughputs"),
            serial_mask=self.gather("serial_mask"),
            configs_trace=configs,
            num_rebalances=sum(t.num_rebalances for t in self.replicas),
            total_trials=sum(t.total_trials for t in self.replicas),
            mitigation_lengths=[m for t in self.replicas
                                for m in t.mitigation_lengths],
            workload=self.workload,
            service_latencies=self.gather("service_latencies"),
            queue_delays=self.gather("queue_delays"),
            arrival_times=self.gather("arrival_times"),
            completion_times=self.gather("completion_times"),
            queue_depths=self.gather("queue_depths"),
            peak_throughput=peak,
            rc_throughputs=rc,
            admission=self.admission,
            slo_latency=self.slo_latency,
            shed_arrivals=self.shed_arrivals,
            num_failed=sum(t.num_failed for t in self.replicas),
            num_retried=sum(t.num_retried for t in self.replicas),
            num_hedged=sum(t.num_hedged for t in self.replicas),
            wasted_time=sum(t.wasted_time for t in self.replicas),
            downtime=sum(t.downtime for t in self.replicas),
            **tier_cols,
        )

    # -- fleet metrics (one metric implementation: PipelineTrace's) ----------
    def tail_latency(self, pct: float = 99.0) -> float:
        return self.fleet.tail_latency(pct)

    @property
    def mean_queue_delay(self) -> float:
        return self.fleet.mean_queue_delay

    @property
    def offered_load(self) -> float:
        """Fleet arrival rate over the run."""
        return self.fleet.offered_load

    @property
    def achieved_load(self) -> float:
        """Fleet completion rate over the run."""
        return self.fleet.achieved_load

    def slo_violations(self, slo_level: float) -> float:
        """Fraction of admitted queries with throughput below
        ``slo_level`` x *their replica's* interference-free peak."""
        peaks = np.array([t.peak_throughput for t in self.replicas])[
            self.assignments[self.admitted_mask]]
        return float(np.mean(self.gather("throughputs")
                             < slo_level * peaks))

    # -- the one summary dict ------------------------------------------------
    def summary(self) -> Dict[str, float]:
        """Flat metric dict: the PipelineTrace surface computed at the
        fleet level plus the cluster-only columns."""
        fleet = self.fleet
        s = fleet.summary()
        peak_known = all(np.isfinite(t.peak_throughput)
                         for t in self.replicas)
        s["slo_violations"] = (
            self.slo_violations(PipelineTrace.SUMMARY_SLO_LEVEL)
            if peak_known else float("nan"))
        s["num_replicas"] = self.num_replicas
        s["router"] = self.router
        s["min_replica_share"] = (float(self.replica_counts.min())
                                  / max(self.num_admitted, 1))
        s["max_replica_share"] = (float(self.replica_counts.max())
                                  / max(self.num_admitted, 1))
        # -- control plane (docs/CONTROL.md) -----------------------------
        s["admission"] = self.admission
        s["autoscaler"] = self.autoscaler
        s["num_shed"] = float(self.num_shed)
        s["shed_rate"] = self.shed_rate
        s["mean_active_replicas"] = (float(self.active_counts.mean())
                                     if self.num_queries
                                     else float(self.num_replicas))
        return s

    def rows(self) -> List[Dict]:
        """Per-replica + fleet metric rows (CSV-ready, one schema)."""
        out = []
        for r, t in enumerate(self.replicas):
            row = {"scope": f"replica{r}", "router": self.router,
                   "workload": self.workload, "scheduler": t.scheduler,
                   "queries": int(self.replica_counts[r])}
            if len(t.latencies):
                row.update(
                    p50_latency=t.percentile(50),
                    p99_latency=t.tail_latency(99),
                    mean_queue_delay=t.mean_queue_delay,
                    steady_throughput=t.steady_throughput,
                    rebalances=t.num_rebalances,
                    total_trials=t.total_trials,
                )
            else:   # a replica the router never picked
                row.update(p50_latency=float("nan"),
                           p99_latency=float("nan"),
                           mean_queue_delay=float("nan"),
                           steady_throughput=float("nan"),
                           rebalances=t.num_rebalances,
                           total_trials=t.total_trials)
            out.append(row)
        s = self.summary()
        out.append({"scope": "fleet", "router": self.router,
                    "workload": self.workload, "scheduler": self.scheduler,
                    "queries": self.num_queries,
                    "p50_latency": s["p50_latency_s"],
                    "p99_latency": s["p99_latency_s"],
                    "mean_queue_delay": s["mean_queue_delay_s"],
                    "steady_throughput": s["steady_throughput_qps"],
                    "rebalances": s["rebalances"],
                    "total_trials": sum(t.total_trials
                                        for t in self.replicas)})
        return out
