"""Multi-replica fleet serving with interference-aware routing.

The cluster is the repo's fourth pluggable axis (after schedulers,
workloads and batching): a :class:`Cluster` owns N pipeline replicas —
each with its own :class:`~repro.schedulers.runtime.RebalanceRuntime`,
detector state, interference timeline and executor — and a
:class:`Router` picks the replica every fleet arrival is dispatched
to.  Built-ins: ``round_robin``, ``least_outstanding`` (cluster-level
LLS) and ``odin_aware`` (routes away from replicas whose ODIN
detectors currently report interference).  See docs/CLUSTER.md.

Backends: :func:`simulate_cluster` (database simulator, replica-scoped
``InterferenceEvent``\\ s) and :func:`serve_cluster` (live
:class:`~repro.serving.ServingEngine` replicas; imported lazily so the
simulator path stays JAX-free).
"""
from repro.cluster.base import ReplicaView, Router  # noqa: F401
from repro.cluster.cluster import (  # noqa: F401
    Cluster,
    Replica,
    run_cluster,
)
from repro.cluster.registry import (  # noqa: F401
    available_routers,
    make_router,
    register_router,
    resolve_router,
    router_class,
    unregister_router,
)
from repro.cluster.sim import simulate_cluster  # noqa: F401
from repro.cluster.trace import ClusterTrace  # noqa: F401


def __getattr__(name):
    """Lazy: ``serve_cluster`` pulls in JAX via the serving engine;
    simulator-only users shouldn't pay that import."""
    if name == "serve_cluster":
        from repro.cluster.live import serve_cluster
        return serve_cluster
    raise AttributeError(f"module {__name__!r} has no attribute {name!r}")
