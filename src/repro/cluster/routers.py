"""Built-in routers: round_robin, least_outstanding, odin_aware, edf,
downgrade.

All three are deterministic (ties break toward the lowest replica
index) so per-replica assignment sequences are reproducible from
``(workload, seed, router)`` — see ``tests/test_cluster.py``.

Routers return a *position* into the views they were handed, not a
fleet replica index: with an :class:`~repro.control.Autoscaler` active
the views cover only the active subset of the fleet (docs/CONTROL.md),
and the cluster maps the position back through ``views[pos].index``.
Without an autoscaler the views span the whole fleet in index order,
so position and index coincide.

* ``round_robin`` — classic stateful cycle; the fleet baseline every
  serving system starts from.  Blind to replica state, so a degraded
  replica keeps receiving its 1/N share.
* ``least_outstanding`` — cluster-level least-loaded scheduling (the
  LLS idea one level up): dispatch to the replica with the fewest
  in-system queries.  Reactive — it only diverts once the degraded
  replica has visibly queued up.
* ``odin_aware`` — interference-aware routing (Strait's thesis applied
  to ODIN's signals): cost each replica by the wait + service a query
  dispatched now would see, inflating replicas whose
  :class:`~repro.schedulers.base.InterferenceDetector` currently
  reports an active bottleneck shift and replicas mid-exploration
  (serial trials drain the pipeline).  Proactive — it diverts the
  moment a detector fires, before a backlog forms.
* ``edf`` — ``odin_aware`` plus an earliest-deadline-first / value-
  density term (docs/QOS.md): a replica whose projected completion
  misses the arrival's deadline pays its value-weighted lateness, so
  high-value near-deadline traffic jumps to the replica that can
  still make it.  Tier-blind arrivals fall through to plain
  ``odin_aware``.
* ``downgrade`` — heterogeneous-fleet QoS routing (docs/QOS.md):
  best-effort traffic routes to the ``"small"`` replica pool when the
  full-model pool is under pressure, instead of shedding; higher
  tiers keep the full-model pool.  Falls through to ``odin_aware``
  within whichever pool is chosen.
"""
from __future__ import annotations

import math
from typing import Sequence

from repro.cluster.base import ReplicaView
from repro.cluster.registry import register_router


@register_router("round_robin")
class RoundRobinRouter:
    """Cycle through replicas in index order."""

    def __init__(self):
        self._next = 0

    def route(self, q: int, now: float,
              views: Sequence[ReplicaView]) -> int:
        r = self._next % len(views)
        self._next = r + 1
        return r

    def reset(self) -> None:
        self._next = 0


@register_router("least_outstanding")
class LeastOutstandingRouter:
    """Fewest in-system queries wins (cluster-level LLS)."""

    def route(self, q: int, now: float,
              views: Sequence[ReplicaView]) -> int:
        best = 0
        for p in range(1, len(views)):
            if views[p].outstanding < views[best].outstanding:
                best = p
        return best

    def reset(self) -> None:
        pass


@register_router("odin_aware")
class OdinAwareRouter:
    """Route by expected completion, penalizing detected interference.

    Per replica the cost is ``backlog + est_bottleneck`` — the
    admission-head wait a query dispatched now would see plus one
    service beat on the committed configuration (both from the
    estimates ODIN's runtime already maintains; an interfered replica's
    estimated beat is inflated by the interference itself, so the base
    cost alone already steers away from degraded replicas).  Two
    multiplicative penalties sharpen "route away":

    * a replica whose detector currently sees a positive bottleneck
      shift pays ``1 + interference_weight * shift`` — continuous in
      the shift (measured-time jitter of a few percent perturbs the
      cost a few percent instead of toggling a hard avoid/admit cliff),
      yet decisive for real interference, where the shift is large;
    * a replica mid-exploration pays ``explore_penalty`` — its queries
      run serially on a drained pipeline until the phase commits.

    **Freshness gating**: a replica's detector/exploration state only
    advances while it serves queries, so both penalties apply only when
    the signal is fresh (the replica served within the last
    ``freshness_window`` fleet queries).  Without the gate a noisy
    measurement on the live engine starves the replica: penalized →
    never routed to → state never refreshed → penalized forever, and
    the fleet collapses onto its neighbours.  The (stale) estimated
    beat still carries the degradation signal after the gate closes.

    ``probe_interval > 0`` additionally routes a query to any replica
    idle that long, refreshing its estimates (how a recovered replica
    re-enters rotation at light load, at the price of occasionally
    sampling a still-degraded one).  Default off: the stale-estimate
    cost ordering re-admits replicas as soon as the fleet backlog
    exceeds their last-known beat.

    Replicas with no estimate yet (live engine before its first
    measurement) cost only their backlog, so cold replicas are seeded
    in index order rather than starved.
    """

    def __init__(self, interference_weight: float = 4.0,
                 explore_penalty: float = 2.0,
                 freshness_window: int = 8,
                 probe_interval: int = 0):
        self.interference_weight = float(interference_weight)
        self.explore_penalty = float(explore_penalty)
        self.freshness_window = int(freshness_window)
        self.probe_interval = int(probe_interval)

    def route(self, q: int, now: float,
              views: Sequence[ReplicaView]) -> int:
        if self.probe_interval > 0:
            stalest = max(range(len(views)),
                          key=lambda p: (views[p].since_assign,
                                         -views[p].index))
            if views[stalest].since_assign > self.probe_interval:
                return stalest
        best, best_cost = 0, self._cost(views[0])
        for p in range(1, len(views)):
            c = self._cost(views[p])
            if c < best_cost:
                best, best_cost = p, c
        return best

    def _cost(self, v: ReplicaView) -> float:
        service = v.est_bottleneck
        if not math.isfinite(service):
            service = 0.0
        cost = v.backlog + service
        if v.since_assign <= self.freshness_window:
            cost *= 1.0 + self.interference_weight * v.interference_score
            if v.exploring:
                cost *= self.explore_penalty
        return cost

    def reset(self) -> None:
        pass


@register_router("edf")
class EdfRouter(OdinAwareRouter):
    """``odin_aware`` + an EDF / value-density lateness term.

    For a tiered arrival (``request`` carries its absolute deadline
    and SLO value, docs/QOS.md) each replica's cost grows by
    ``value_weight x value x max(0, eta - deadline)`` where ``eta``
    is the projected completion on that replica (now + backlog + one
    estimated service latency).  Replicas that can still make the
    deadline pay nothing extra — the interference-aware base cost
    decides between them exactly as ``odin_aware`` would — while a
    high-value query facing lateness is pushed hard toward whichever
    replica minimizes its value-weighted tardiness (earliest-deadline-
    first pressure, expressed as routing cost rather than queue
    reordering, so group-synchronous dispatch semantics are
    untouched).  Arrivals without a finite deadline — and runs with no
    tiers configured at all — fall through to plain ``odin_aware``.
    """

    def __init__(self, value_weight: float = 1.0, **kwargs):
        super().__init__(**kwargs)
        if value_weight < 0:
            raise ValueError(f"value_weight must be >= 0, "
                             f"got {value_weight}")
        self.value_weight = float(value_weight)

    def route(self, q: int, now: float, views: Sequence[ReplicaView],
              request=None) -> int:
        if request is None or not math.isfinite(request.deadline):
            return super().route(q, now, views)
        best, best_cost = 0, self._edf_cost(views[0], now, request)
        for p in range(1, len(views)):
            c = self._edf_cost(views[p], now, request)
            if c < best_cost:
                best, best_cost = p, c
        return best

    def _edf_cost(self, v: ReplicaView, now: float, request) -> float:
        est = v.est_latency
        if not math.isfinite(est):
            est = v.est_bottleneck
        if not math.isfinite(est):
            est = 0.0
        eta = now + v.backlog + est
        lateness = max(0.0, eta - request.deadline)
        return (self._cost(v)
                + self.value_weight * request.value * lateness)


@register_router("downgrade")
class DowngradeRouter(OdinAwareRouter):
    """Best-effort traffic downgrades to small-model replicas under
    pressure instead of shedding (docs/QOS.md).

    The fleet partitions into pools by :attr:`ReplicaView.pool`:
    ``"small"`` replicas serve a cheaper model (heterogeneous fleets);
    everything else is the full-model pool.  Arrivals with priority
    above ``priority_max`` always route within the full-model pool
    (when one is in the view set).  An arrival at or below
    ``priority_max`` routes to the small pool when the full-model
    pool's cheapest backlog exceeds ``pressure`` (time units) — the
    answer quality degrades, the deadline survives, and the full
    models keep their headroom for the traffic that values it.  Each
    downgrade is counted per tier in :attr:`downgrade_counts`, which
    the cluster folds into the run's per-tier accounting.

    Within the chosen pool the decision is plain ``odin_aware`` cost;
    untier-ed runs (``request`` always ``None``) never downgrade.
    """

    def __init__(self, pressure: float = 0.0, priority_max: int = 0,
                 **kwargs):
        super().__init__(**kwargs)
        if pressure < 0:
            raise ValueError(f"pressure must be >= 0, got {pressure}")
        self.pressure = float(pressure)
        self.priority_max = int(priority_max)
        self.downgrade_counts: dict = {}

    def route(self, q: int, now: float, views: Sequence[ReplicaView],
              request=None) -> int:
        small = [p for p in range(len(views))
                 if views[p].pool == "small"]
        full = [p for p in range(len(views))
                if views[p].pool != "small"]
        if request is not None and small and full:
            if request.priority > self.priority_max:
                return self._cheapest(views, full)
            if min(views[p].backlog for p in full) > self.pressure:
                pos = self._cheapest(views, small)
                self.downgrade_counts[request.tier] = (
                    self.downgrade_counts.get(request.tier, 0) + 1)
                return pos
        pool = full or list(range(len(views)))
        return self._cheapest(views, pool)

    def _cheapest(self, views: Sequence[ReplicaView],
                  positions: Sequence[int]) -> int:
        best = positions[0]
        best_cost = self._cost(views[best])
        for p in positions[1:]:
            c = self._cost(views[p])
            if c < best_cost:
                best, best_cost = p, c
        return best

    def reset(self) -> None:
        self.downgrade_counts = {}
