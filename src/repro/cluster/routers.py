"""Built-in routers: round_robin, least_outstanding, odin_aware.

All three are deterministic (ties break toward the lowest replica
index) so per-replica assignment sequences are reproducible from
``(workload, seed, router)`` — see ``tests/test_cluster.py``.

Routers return a *position* into the views they were handed, not a
fleet replica index: with an :class:`~repro.control.Autoscaler` active
the views cover only the active subset of the fleet (docs/CONTROL.md),
and the cluster maps the position back through ``views[pos].index``.
Without an autoscaler the views span the whole fleet in index order,
so position and index coincide.

* ``round_robin`` — classic stateful cycle; the fleet baseline every
  serving system starts from.  Blind to replica state, so a degraded
  replica keeps receiving its 1/N share.
* ``least_outstanding`` — cluster-level least-loaded scheduling (the
  LLS idea one level up): dispatch to the replica with the fewest
  in-system queries.  Reactive — it only diverts once the degraded
  replica has visibly queued up.
* ``odin_aware`` — interference-aware routing (Strait's thesis applied
  to ODIN's signals): cost each replica by the wait + service a query
  dispatched now would see, inflating replicas whose
  :class:`~repro.schedulers.base.InterferenceDetector` currently
  reports an active bottleneck shift and replicas mid-exploration
  (serial trials drain the pipeline).  Proactive — it diverts the
  moment a detector fires, before a backlog forms.
"""
from __future__ import annotations

import math
from typing import Sequence

from repro.cluster.base import ReplicaView
from repro.cluster.registry import register_router


@register_router("round_robin")
class RoundRobinRouter:
    """Cycle through replicas in index order."""

    def __init__(self):
        self._next = 0

    def route(self, q: int, now: float,
              views: Sequence[ReplicaView]) -> int:
        r = self._next % len(views)
        self._next = r + 1
        return r

    def reset(self) -> None:
        self._next = 0


@register_router("least_outstanding")
class LeastOutstandingRouter:
    """Fewest in-system queries wins (cluster-level LLS)."""

    def route(self, q: int, now: float,
              views: Sequence[ReplicaView]) -> int:
        best = 0
        for p in range(1, len(views)):
            if views[p].outstanding < views[best].outstanding:
                best = p
        return best

    def reset(self) -> None:
        pass


@register_router("odin_aware")
class OdinAwareRouter:
    """Route by expected completion, penalizing detected interference.

    Per replica the cost is ``backlog + est_bottleneck`` — the
    admission-head wait a query dispatched now would see plus one
    service beat on the committed configuration (both from the
    estimates ODIN's runtime already maintains; an interfered replica's
    estimated beat is inflated by the interference itself, so the base
    cost alone already steers away from degraded replicas).  Two
    multiplicative penalties sharpen "route away":

    * a replica whose detector currently sees a positive bottleneck
      shift pays ``1 + interference_weight * shift`` — continuous in
      the shift (measured-time jitter of a few percent perturbs the
      cost a few percent instead of toggling a hard avoid/admit cliff),
      yet decisive for real interference, where the shift is large;
    * a replica mid-exploration pays ``explore_penalty`` — its queries
      run serially on a drained pipeline until the phase commits.

    **Freshness gating**: a replica's detector/exploration state only
    advances while it serves queries, so both penalties apply only when
    the signal is fresh (the replica served within the last
    ``freshness_window`` fleet queries).  Without the gate a noisy
    measurement on the live engine starves the replica: penalized →
    never routed to → state never refreshed → penalized forever, and
    the fleet collapses onto its neighbours.  The (stale) estimated
    beat still carries the degradation signal after the gate closes.

    ``probe_interval > 0`` additionally routes a query to any replica
    idle that long, refreshing its estimates (how a recovered replica
    re-enters rotation at light load, at the price of occasionally
    sampling a still-degraded one).  Default off: the stale-estimate
    cost ordering re-admits replicas as soon as the fleet backlog
    exceeds their last-known beat.

    Replicas with no estimate yet (live engine before its first
    measurement) cost only their backlog, so cold replicas are seeded
    in index order rather than starved.
    """

    def __init__(self, interference_weight: float = 4.0,
                 explore_penalty: float = 2.0,
                 freshness_window: int = 8,
                 probe_interval: int = 0):
        self.interference_weight = float(interference_weight)
        self.explore_penalty = float(explore_penalty)
        self.freshness_window = int(freshness_window)
        self.probe_interval = int(probe_interval)

    def route(self, q: int, now: float,
              views: Sequence[ReplicaView]) -> int:
        if self.probe_interval > 0:
            stalest = max(range(len(views)),
                          key=lambda p: (views[p].since_assign,
                                         -views[p].index))
            if views[stalest].since_assign > self.probe_interval:
                return stalest
        best, best_cost = 0, self._cost(views[0])
        for p in range(1, len(views)):
            c = self._cost(views[p])
            if c < best_cost:
                best, best_cost = p, c
        return best

    def _cost(self, v: ReplicaView) -> float:
        service = v.est_bottleneck
        if not math.isfinite(service):
            service = 0.0
        cost = v.backlog + service
        if v.since_assign <= self.freshness_window:
            cost *= 1.0 + self.interference_weight * v.interference_score
            if v.exploring:
                cost *= self.explore_penalty
        return cost

    def reset(self) -> None:
        pass
