"""Database-simulator fleet backend: ``simulate_cluster``.

The cluster analogue of :func:`repro.core.simulator.simulate`: every
replica gets its own :class:`DatabaseQueryExecutor` (its *own* view of
the fleet event list — replica-scoped events via
``InterferenceEvent.replica`` — and its own scenario state), its own
scheduler policy + :class:`RebalanceRuntime`, and the shared DP-oracle
cache for resource-constrained references.  Replica-scoped interference
is therefore a first-class scenario: an event with ``replica=2`` hits
replica 2's pipeline and nothing else, and the router's job is to see
it (via replica 2's detector) and steer the fleet around it.

Event anchoring: query-indexed events count each *replica's local*
queries (natural for closed-loop fleets); ``events_time_indexed=True``
anchors the windows on the fleet arrival clock instead — the stressor
runs wall-clock intervals, replicas serving different query counts see
the same episode — which requires an open-loop workload.
"""
from __future__ import annotations

from typing import List, Optional, Sequence, Union

from repro.cluster.cluster import Replica, _run_cluster_impl
from repro.cluster.trace import ClusterTrace
from repro.core.database import LayerDatabase
from repro.core.events import InterferenceEvent, events_for_replica
from repro.core.exhaustive import optimal_partition
from repro.core.pipeline_state import balanced_config, throughput
from repro.core.simulator import DatabaseQueryExecutor, SimTimeSource
from repro.schedulers.registry import make_scheduler
from repro.schedulers.runtime import RebalanceRuntime
from repro.workloads.base import Workload
from repro.workloads.runner import resolve_workload


def _simulate_cluster_impl(db: LayerDatabase,
                     num_eps: int,
                     num_replicas: int,
                     scheduler: str = "odin",
                     router: Union[str, object, None] = "round_robin",
                     alpha: int = 10,
                     num_queries: int = 4000,
                     events: Optional[Sequence[InterferenceEvent]] = None,
                     rel_threshold: Optional[float] = None,
                     initial_config: Optional[List[int]] = None,
                     workload: Union[str, Workload, None] = "closed",
                     workload_kwargs: Optional[dict] = None,
                     events_time_indexed: bool = False,
                     router_kwargs: Optional[dict] = None,
                     admission: Union[str, object, None] = None,
                     admission_kwargs: Optional[dict] = None,
                     autoscaler: Union[str, object, None] = None,
                     autoscaler_kwargs: Optional[dict] = None,
                     max_batch: int = 1,
                     trace_mode: str = "dense",
                     metrics_sink=None,
                     sink_interval: Optional[int] = None,
                     faults=None,
                     retries=None,
                     hedge_after: Optional[float] = None,
                     health_kwargs: Optional[dict] = None,
                     when_all_unhealthy: str = "wait",
                     databases: Optional[Sequence[LayerDatabase]] = None,
                     pools: Optional[Sequence[str]] = None,
                     tiers=None,
                     tiers_kwargs: Optional[dict] = None
                     ) -> ClusterTrace:
    """Run one (scheduler, router, workload, events) fleet simulation.

    ``events`` is the *fleet* event list: each
    :class:`InterferenceEvent` hits one replica
    (``replica=<index>``) or all of them (``replica=None``); default —
    no interference, the routing-baseline setting.  ``scheduler`` is a
    registry name constructed *per replica* (each replica needs its own
    detector/explorer state).  The DP-oracle cache is shared across
    replicas (one database); the clean-optimum starting configuration
    and its peak throughput are computed once and stamped on every
    replica, exactly as :func:`~repro.core.simulator.simulate` does for
    a single pipeline.

    ``admission`` / ``autoscaler`` select the fleet's SLO control plane
    (:mod:`repro.control`, docs/CONTROL.md): e.g.
    ``admission="slo_shed", admission_kwargs={"slo": ...}`` sheds
    arrivals no replica could serve within the SLO, and
    ``autoscaler="load_profile"`` activates/drains replicas off the
    rolling offered load.  Defaults leave both off (bit-identical to
    the pre-control-plane fleet).

    ``max_batch > 1`` opts into fleet rebatching (docs/CLUSTER.md):
    same-replica routing streaks of open-loop arrivals flush through
    the replica's vectorized ``step_many`` instead of query-by-query
    steps.  Default 1 is the exact per-query path.

    ``faults`` injects deterministic failures (docs/FAULTS.md): a
    :class:`~repro.faults.FaultPlan`, a spec string such as
    ``"crash@100+50:r=1"``, or a list of either; each replica's
    executor is wrapped with its slice of the plan
    (``FaultEvent.replica`` targets one replica, ``None`` all).
    ``retries`` / ``hedge_after`` / ``health_kwargs`` /
    ``when_all_unhealthy`` configure the fleet's recovery machinery
    (retry budget + backoff, tail-latency hedging, circuit-breaker
    routing).  All default off — bit-identical to a fault-free build.

    Heterogeneous fleets (docs/QOS.md): ``databases`` gives replica
    ``r`` its own :class:`LayerDatabase` (cost model) — each distinct
    database gets its own clean-optimum starting configuration, peak
    throughput and DP-oracle cache, so a fleet can mix full-model and
    small-model replicas.  ``pools`` labels replicas for pool-aware
    routers (``"small"`` marks downgrade targets).  ``tiers`` /
    ``tiers_kwargs`` arm QoS tier stamping over the fleet arrivals
    (:func:`repro.qos.resolve_tiers` forms); all default off.
    """
    if num_replicas < 1:
        raise ValueError("num_replicas must be >= 1")
    if databases is not None:
        databases = list(databases)
        if len(databases) != num_replicas:
            raise ValueError(f"databases must give one LayerDatabase per "
                             f"replica: got {len(databases)} for "
                             f"{num_replicas} replicas")
    else:
        databases = [db] * num_replicas
    if pools is not None:
        pools = [str(p) for p in pools]
        if len(pools) != num_replicas:
            raise ValueError(f"pools must label every replica: got "
                             f"{len(pools)} for {num_replicas} replicas")
    else:
        pools = ["default"] * num_replicas
    plan = None
    if faults is not None:
        from repro.faults import resolve_faults
        plan = resolve_faults(faults, time_indexed=events_time_indexed)
    fleet_events = list(events) if events is not None else []
    # A time-indexed fault plan anchors its windows on the arrival
    # clock, exactly like time-indexed interference events — both need
    # the per-replica arrival feed (and an open-loop workload).
    time_anchored = events_time_indexed or (plan is not None
                                            and plan.time_indexed)
    if time_anchored:
        # Resolve once so the misuse fails here with the same clear
        # error the single-pipeline path gives, not deep in the
        # timeline on the first routed query.
        wl = resolve_workload(workload, workload_kwargs)
        if not wl.open_loop:
            raise ValueError(
                "time-indexed interference events need an open-loop "
                "workload: a closed loop has no arrival clock to anchor "
                "the event windows on")
        workload, workload_kwargs = wl, None

    # One oracle cache + clean-optimum reference *per distinct
    # database*: the optimum only depends on the scenario vector and
    # the database, so homogeneous fleets share everything exactly as
    # before, while a heterogeneous fleet's small-model replicas get
    # their own configurations and peaks.
    per_db: dict = {}

    def _db_state(d: LayerDatabase):
        key = id(d)
        if key not in per_db:
            cfg = (list(initial_config) if initial_config is not None
                   else balanced_config(d.num_layers, num_eps))
            clean = SimTimeSource(d, [0] * num_eps)
            if initial_config is None:
                cfg, _ = optimal_partition(d, [0] * num_eps, num_eps)
            cache: dict = {}

            def _oracle(scen_key, _d=d, _cache=cache):
                if scen_key not in _cache:
                    _cache[scen_key] = optimal_partition(
                        _d, list(scen_key), num_eps)
                return _cache[scen_key]

            per_db[key] = (cfg, throughput(clean.stage_times(cfg)),
                           _oracle)
        return per_db[key]

    replicas = []
    for r in range(num_replicas):
        rdb = databases[r]
        config0, peak, _oracle = _db_state(rdb)
        executor = DatabaseQueryExecutor(
            rdb, num_eps, events_for_replica(fleet_events, r), _oracle,
            time_indexed=events_time_indexed)
        if plan is not None:
            from repro.faults import FaultingExecutor
            from repro.faults.retry import resolve_retries
            spec = resolve_retries(retries)
            executor = FaultingExecutor(
                executor, plan, replica=r,
                timeout=(spec.timeout if spec is not None else None))

        def solver(cfg, src, _ex=executor, _oracle=_oracle) -> List[int]:
            return list(_oracle(tuple(_ex.scenarios))[0])

        policy = make_scheduler(scheduler, alpha=alpha,
                                rel_threshold=rel_threshold, solver=solver)
        runtime = RebalanceRuntime(policy, config0)

        on_assign = None
        if time_anchored:
            clock: List[Optional[float]] = []
            executor.set_arrivals(clock)

            def on_assign(fq, lq, arrival, _clock=clock):
                # Keyed on the local index, not appended: a failed
                # dispatch serves no row, so a retry re-assigns the
                # same slot (docs/FAULTS.md) and must overwrite it.
                if lq < len(_clock):
                    _clock[lq] = arrival
                else:
                    _clock.extend([arrival] * (lq + 1 - len(_clock)))

        replicas.append(Replica(executor=executor, runtime=runtime,
                                peak_throughput=peak,
                                pool=pools[r],
                                on_assign=on_assign))

    return _run_cluster_impl(replicas, num_queries, workload=workload,
                       workload_kwargs=workload_kwargs, router=router,
                       router_kwargs=router_kwargs,
                       scheduler_name=scheduler,
                       admission=admission,
                       admission_kwargs=admission_kwargs,
                       autoscaler=autoscaler,
                       autoscaler_kwargs=autoscaler_kwargs,
                       max_batch=max_batch,
                       trace_mode=trace_mode, metrics_sink=metrics_sink,
                       sink_interval=sink_interval,
                       retries=retries, hedge_after=hedge_after,
                       health_kwargs=health_kwargs,
                       when_all_unhealthy=when_all_unhealthy,
                       tiers=tiers, tiers_kwargs=tiers_kwargs)


def simulate_cluster(db: LayerDatabase,
                     num_eps: int,
                     num_replicas: int,
                     scheduler: str = "odin",
                     router: Union[str, object, None] = "round_robin",
                     alpha: int = 10,
                     num_queries: int = 4000,
                     events: Optional[Sequence[InterferenceEvent]] = None,
                     rel_threshold: Optional[float] = None,
                     initial_config: Optional[List[int]] = None,
                     workload: Union[str, Workload, None] = "closed",
                     workload_kwargs: Optional[dict] = None,
                     events_time_indexed: bool = False,
                     router_kwargs: Optional[dict] = None,
                     admission: Union[str, object, None] = None,
                     admission_kwargs: Optional[dict] = None,
                     autoscaler: Union[str, object, None] = None,
                     autoscaler_kwargs: Optional[dict] = None,
                     max_batch: int = 1,
                     trace_mode: str = "dense",
                     metrics_sink=None,
                     sink_interval: Optional[int] = None,
                     faults=None,
                     retries=None,
                     hedge_after: Optional[float] = None,
                     health_kwargs: Optional[dict] = None,
                     when_all_unhealthy: str = "wait",
                     databases: Optional[Sequence[LayerDatabase]] = None,
                     pools: Optional[Sequence[str]] = None,
                     tiers=None,
                     tiers_kwargs: Optional[dict] = None
                     ) -> ClusterTrace:
    """Run one (scheduler, router, workload, events) fleet simulation.

    Thin wrapper over the unified :class:`repro.api.RunSpec` path (one
    declaration, one dispatcher — docs/API.md); the kwargs here map
    1:1 onto spec fields and new options land on the spec instead of
    this signature.  See :func:`_simulate_cluster_impl` for the full
    kwarg-level documentation.
    """
    from repro import api
    spec = api.RunSpec(
        db=db, num_eps=num_eps, num_queries=num_queries,
        events=events, events_time_indexed=events_time_indexed,
        scheduler=api.SchedulerSpec(name=scheduler, alpha=alpha,
                                    rel_threshold=rel_threshold,
                                    initial_config=initial_config),
        workload=api.WorkloadSpec(name=workload, kwargs=workload_kwargs),
        admission=api.AdmissionSpec(name=admission,
                                    kwargs=admission_kwargs),
        faults=api.FaultsSpec(plan=faults, hedge_after=hedge_after,
                              health_kwargs=health_kwargs,
                              when_all_unhealthy=when_all_unhealthy),
        retries=api.RetriesSpec(policy=retries),
        tiers=api.TiersSpec(spec=tiers, kwargs=tiers_kwargs),
        telemetry=api.TelemetrySpec(trace_mode=trace_mode,
                                    metrics_sink=metrics_sink,
                                    sink_interval=sink_interval),
        cluster=api.ClusterSpec(num_replicas=num_replicas,
                                router=router,
                                router_kwargs=router_kwargs,
                                autoscaler=autoscaler,
                                autoscaler_kwargs=autoscaler_kwargs,
                                max_batch=max_batch,
                                pools=(tuple(pools) if pools is not None
                                       else None),
                                databases=databases))
    return api.run(spec)
