"""String-keyed router registry (mirrors ``schedulers``/``workloads``).

Routers register under a name and are constructed through
``make_router(name, **kwargs)``; kwargs are filtered against each
class's ``__init__`` so one superset of knobs constructs any router
(``interference_weight`` means nothing to ``round_robin``).
"""
from __future__ import annotations

from typing import Callable, List, Type, Union

from repro.util.registry import Registry

# Importing the routers module runs its @register_router decorators;
# lazy so registry.py itself stays import-cycle-free.
_REGISTRY = Registry("router", builtins_module="repro.cluster.routers")


def register_router(name: str, **defaults) -> Callable[[Type], Type]:
    """Class decorator registering a Router under ``name``."""
    return _REGISTRY.register(name, **defaults)


def unregister_router(name: str) -> None:
    """Remove a registration (tests / plugin reload)."""
    _REGISTRY.unregister(name)


def available_routers() -> List[str]:
    """Sorted names of every registered router."""
    return _REGISTRY.available()


def router_class(name: str) -> Type:
    return _REGISTRY.cls(name)


def make_router(name: str, **kwargs):
    """Construct the router registered under ``name``."""
    return _REGISTRY.make(name, **kwargs)


def resolve_router(router: Union[str, object, None],
                   router_kwargs=None):
    """Name (+ kwargs) or instance -> Router instance."""
    if router is None:
        router = "round_robin"
    if isinstance(router, str):
        return make_router(router, **(router_kwargs or {}))
    if router_kwargs:
        raise ValueError("router_kwargs only apply to a router name, "
                         "not an already-constructed instance")
    return router
