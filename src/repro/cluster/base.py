"""Router protocol + the per-replica view routers decide over.

A :class:`Router` is the cluster's third pluggable axis, mirroring
``repro.schedulers`` (mitigation policy) and ``repro.workloads``
(arrival process): given the fleet's next arrival and a read-only
:class:`ReplicaView` per replica, it picks the replica the query is
dispatched to.  Routers must be **deterministic** — a pure function of
their own state and the views — so a run is reproducible from
``(workload, seed, router)`` alone, and so the ``cluster(n=1)``
reduction is trace-identical to a plain :func:`~repro.workloads.run_pipeline`.

The view exposes exactly the signals ODIN's per-pipeline machinery
already maintains (PR 1-3): the admission ledger (outstanding work /
backlog), the :class:`~repro.schedulers.runtime.RebalanceRuntime`'s
exploration state, and the policy's
:class:`~repro.schedulers.base.InterferenceDetector` probed
side-effect-free (``detector.shift``) together with the runtime's
stage-time estimates — which is what lets the ``odin_aware`` router
route *away* from replicas whose detectors currently report
interference without consuming any detector observations.
"""
from __future__ import annotations

from typing import TYPE_CHECKING, Protocol, Sequence, runtime_checkable

if TYPE_CHECKING:  # annotation-only
    from repro.workloads.runner import PipelineRunner


class ReplicaView:
    """Read-only snapshot of one replica at a routing decision.

    ``outstanding`` (queries in-system at the decision time) is computed
    by the cluster's ledger; every other signal is probed lazily from
    the replica's runner/runtime, so routers that ignore a field
    (``round_robin`` ignores all of them) never pay for it.
    """

    __slots__ = ("index", "outstanding", "now", "since_assign", "pool",
                 "_runner")

    def __init__(self, index: int, runner: "PipelineRunner",
                 outstanding: int, now: float,
                 since_assign: float = float("inf"),
                 pool: str = "default"):
        self.index = index
        self.outstanding = outstanding
        self.now = now
        #: Replica pool label (heterogeneous fleets, docs/QOS.md):
        #: ``"small"`` marks small-model replicas the ``downgrade``
        #: router may send best-effort traffic to under pressure.
        self.pool = pool
        #: Fleet queries since this replica last served one (``inf`` if
        #: never).  Detector/estimate signals only advance when the
        #: replica serves, so this is the *staleness* of every probed
        #: field below — routers must not treat a long-starved
        #: replica's last reading as current (docs/CLUSTER.md).
        self.since_assign = since_assign
        self._runner = runner

    @property
    def free_at(self) -> float:
        """When this replica's admission head frees up."""
        return self._runner.free_at

    @property
    def backlog(self) -> float:
        """Admission-head wait a query dispatched now would see."""
        return max(self._runner.free_at - self.now, 0.0)

    @property
    def exploring(self) -> bool:
        """True while the replica is mid-rebalance (serial trials —
        the pipeline is drained between queries)."""
        return self._runner.runtime.exploring

    @property
    def interference_score(self) -> float:
        """Positive relative bottleneck degradation the replica's
        detector currently sees (0.0 when quiet / no detector)."""
        return self._runner.runtime.interference_score()

    @property
    def interference_active(self) -> bool:
        """True when the detector's shift exceeds its threshold."""
        return self._runner.runtime.interference_active()

    @property
    def est_bottleneck(self) -> float:
        """Estimated per-query service beat (bottleneck stage time) on
        the replica's committed config; NaN before any poll."""
        return self._runner.runtime.estimated_bottleneck()

    @property
    def est_latency(self) -> float:
        """Estimated end-to-end (pipelined) latency of one query on
        the replica's committed config; NaN before any poll.  What
        fleet-level admission policies compare against an SLO
        (docs/CONTROL.md)."""
        return self._runner.runtime.estimated_service_latency()


@runtime_checkable
class Router(Protocol):
    """Picks the replica each fleet arrival is dispatched to."""

    def route(self, q: int, now: float,
              views: Sequence[ReplicaView]) -> int:
        """Position into ``views`` for fleet query ``q`` at ``now``.

        Must be deterministic given the router's state and the views,
        and must return a position in ``range(len(views))``.  The
        views may cover only the fleet's *active* subset (autoscaling,
        docs/CONTROL.md); the cluster resolves the position to a fleet
        replica via ``views[pos].index``.

        Tier-aware routers (``edf``, ``downgrade``; docs/QOS.md) may
        additionally accept a ``request`` keyword — the cluster
        detects the parameter by signature and passes the arrival's
        :class:`~repro.qos.QosRequest` when tiers are armed, ``None``
        otherwise; routers without the parameter are called exactly as
        before.
        """
        ...

    def reset(self) -> None:
        """Drop routing state (fresh serving window)."""
        ...
