"""Live-engine fleet backend: ``serve_cluster``.

Serves a fleet of :class:`~repro.serving.ServingEngine` replicas —
real JAX execution, measured wall-clock stage times, per-replica
EMA estimates and EMA/hysteresis detectors — behind one routed arrival
queue.  Each engine keeps its own scheduler runtime and online
block-time estimates (that *is* the replica's identity); the jitted
pipeline executor can be shared across engines
(``ServingEngine(..., executor=shared)``) since replicas serve the
same model.

Replica-scoped interference is injected exactly like single-engine
serving: one slowdown schedule per replica
(``schedules[r](local_q) -> per-EP factors``), so "interference hits
only replica 2" is simply a schedule that slows replica 2's EPs while
the others return all-ones.

Queries execute sequentially on this host (the replicas emulate a
fleet the way the single engine emulates co-located tenants), but the
arrival/queueing ledger is per replica in the workload's wall-clock
units — the same convention ``ServingEngine.serve`` uses for open-loop
runs.
"""
from __future__ import annotations

from typing import Callable, List, Optional, Sequence, Union

from repro.cluster.cluster import Replica, _run_cluster_impl
from repro.cluster.trace import ClusterTrace
from repro.workloads.base import Workload


def _serve_cluster_impl(engines: Sequence,
                  queries: Sequence,
                  schedules: Union[Callable, Sequence[Callable]],
                  workload: Union[str, Workload, None] = "closed",
                  workload_kwargs: Optional[dict] = None,
                  router: Union[str, object, None] = "round_robin",
                  router_kwargs: Optional[dict] = None,
                  admission: Union[str, object, None] = None,
                  admission_kwargs: Optional[dict] = None,
                  autoscaler: Union[str, object, None] = None,
                  autoscaler_kwargs: Optional[dict] = None,
                  max_batch: int = 1,
                  trace_mode: str = "dense",
                  metrics_sink=None,
                  sink_interval: Optional[int] = None,
                  faults=None,
                  retries=None,
                  hedge_after: Optional[float] = None,
                  health_kwargs: Optional[dict] = None,
                  when_all_unhealthy: str = "wait",
                  pools: Optional[Sequence[str]] = None,
                  tiers=None,
                  tiers_kwargs: Optional[dict] = None) -> ClusterTrace:
    """Serve fleet ``queries`` through N live engines behind a router.

    ``engines`` — one :class:`~repro.serving.ServingEngine` per
    replica (each owns its runtime/detector/estimates).  ``schedules``
    — per-replica slowdown schedule ``(local_q) -> per-EP factors``, or
    one callable applied to every replica.  The returned trace's
    per-replica peak references are stamped from each engine's online
    clean estimates after the run (NaN for replicas that never served
    a query).

    ``admission`` / ``autoscaler`` select the SLO control plane
    (:mod:`repro.control`, docs/CONTROL.md), identically to
    :func:`~repro.cluster.simulate_cluster` — SLOs are in wall-clock
    seconds here.  Shed queries never touch an engine.

    ``max_batch > 1`` opts into fleet rebatching (docs/CLUSTER.md):
    same-replica routing streaks of open-loop arrivals stack through
    each engine's ``run_batch`` (one set of stage dispatches per
    streak) instead of executing query-by-query.

    ``faults`` / ``retries`` / ``hedge_after`` / ``health_kwargs`` /
    ``when_all_unhealthy`` arm the fleet's fault machinery
    (docs/FAULTS.md): each engine's executor is wrapped with its slice
    of the fault plan, failed dispatches are retried with backoff
    across healthy replicas, and a recovering replica re-warms its XLA
    dispatch shapes (``warm_buckets``) off the timed path before its
    half-open probe.  All default off — fault-free serving is
    unchanged.

    Heterogeneous fleets (docs/QOS.md): ``engines`` may wrap distinct
    :class:`~repro.models.PipelineModel` builds (each engine keeps its
    own jitted executor and warmed-shape caches; all models must accept
    the shared ``queries`` token arrays).  ``pools`` labels replicas
    for pool-aware routers (``"small"`` marks downgrade targets), and
    ``tiers`` / ``tiers_kwargs`` arm QoS tier stamping over the fleet
    arrivals — the stamping runs in the shared fleet loop, so a sim run
    with the same seed sees the identical tier sequence.
    """
    if len(engines) < 1:
        raise ValueError("serve_cluster needs at least one engine")
    if pools is not None:
        pools = [str(p) for p in pools]
        if len(pools) != len(engines):
            raise ValueError(f"pools must label every replica: got "
                             f"{len(pools)} for {len(engines)} engines")
    else:
        pools = ["default"] * len(engines)
    if callable(schedules):
        schedules = [schedules] * len(engines)
    if len(schedules) != len(engines):
        raise ValueError(f"{len(engines)} engines but "
                         f"{len(schedules)} slowdown schedules")
    plan = None
    if faults is not None:
        from repro.faults import resolve_faults
        plan = resolve_faults(faults, time_indexed=True)

    replicas = []
    for r, (eng, schedule) in enumerate(zip(engines, schedules)):
        local_queries: List = []
        executor = eng.query_executor(local_queries, schedule,
                                      max_batch=max_batch)
        clock: List[Optional[float]] = []
        if plan is not None:
            from repro.faults import FaultingExecutor
            from repro.faults.retry import resolve_retries
            spec = resolve_retries(retries)
            executor = FaultingExecutor(
                executor, plan, replica=r,
                timeout=(spec.timeout if spec is not None else None))
            # Fault windows anchor on the workload's arrival clock;
            # the per-replica feed is maintained by on_assign below.
            executor.set_arrivals(clock)

        def on_assign(fleet_q, local_q, arrival, _lq=local_queries,
                      _clock=clock):
            # Keyed on the local index, not appended: a failed
            # dispatch serves no row, so a retry re-assigns the same
            # slot (docs/FAULTS.md) and must overwrite it.
            if local_q < len(_lq):
                _lq[local_q] = queries[fleet_q]
                _clock[local_q] = arrival
            else:
                pad = local_q + 1 - len(_lq)
                _lq.extend([queries[fleet_q]] * pad)
                _clock.extend([arrival] * pad)

        def on_recover(now, _eng=eng, _lq=local_queries):
            # Cold restart: re-warm the engine's dispatch shapes off
            # the timed path before the half-open probe takes traffic.
            seqs = sorted({int(t.shape[-1]) for t in _lq}) or [1]
            _eng.executor.warm_buckets(seqs, max_batch)

        replicas.append(Replica(executor=executor, runtime=eng.runtime,
                                pool=pools[r],
                                on_assign=on_assign,
                                on_recover=on_recover))

    trace = _run_cluster_impl(replicas, len(queries), workload=workload,
                        workload_kwargs=workload_kwargs, router=router,
                        router_kwargs=router_kwargs,
                        scheduler_name=getattr(engines[0], "scheduler", ""),
                        admission=admission,
                        admission_kwargs=admission_kwargs,
                        autoscaler=autoscaler,
                        autoscaler_kwargs=autoscaler_kwargs,
                        max_batch=max_batch,
                        trace_mode=trace_mode, metrics_sink=metrics_sink,
                        sink_interval=sink_interval,
                        retries=retries, hedge_after=hedge_after,
                        health_kwargs=health_kwargs,
                        when_all_unhealthy=when_all_unhealthy,
                        tiers=tiers, tiers_kwargs=tiers_kwargs)
    # Peak references only exist after measurement — stamp post-hoc,
    # exactly like ServingEngine.serve does for a single pipeline.
    for rep_trace, eng in zip(trace.replicas, engines):
        rep_trace.peak_throughput = eng.estimated_peak_throughput()
    return trace


def serve_cluster(engines: Sequence,
                  queries: Sequence,
                  schedules: Union[Callable, Sequence[Callable]],
                  workload: Union[str, Workload, None] = "closed",
                  workload_kwargs: Optional[dict] = None,
                  router: Union[str, object, None] = "round_robin",
                  router_kwargs: Optional[dict] = None,
                  admission: Union[str, object, None] = None,
                  admission_kwargs: Optional[dict] = None,
                  autoscaler: Union[str, object, None] = None,
                  autoscaler_kwargs: Optional[dict] = None,
                  max_batch: int = 1,
                  trace_mode: str = "dense",
                  metrics_sink=None,
                  sink_interval: Optional[int] = None,
                  faults=None,
                  retries=None,
                  hedge_after: Optional[float] = None,
                  health_kwargs: Optional[dict] = None,
                  when_all_unhealthy: str = "wait",
                  pools: Optional[Sequence[str]] = None,
                  tiers=None,
                  tiers_kwargs: Optional[dict] = None) -> ClusterTrace:
    """Serve fleet ``queries`` through N live engines behind a router.

    Thin wrapper over the unified :class:`repro.api.RunSpec` path (one
    declaration, one dispatcher — docs/API.md); the kwargs here map
    1:1 onto spec fields and new options land on the spec instead of
    this signature.  See :func:`_serve_cluster_impl` for the full
    kwarg-level documentation.
    """
    from repro import api
    spec = api.RunSpec(
        engines=engines, queries=queries, schedule=schedules,
        workload=api.WorkloadSpec(name=workload, kwargs=workload_kwargs),
        admission=api.AdmissionSpec(name=admission,
                                    kwargs=admission_kwargs),
        faults=api.FaultsSpec(plan=faults, hedge_after=hedge_after,
                              health_kwargs=health_kwargs,
                              when_all_unhealthy=when_all_unhealthy),
        retries=api.RetriesSpec(policy=retries),
        tiers=api.TiersSpec(spec=tiers, kwargs=tiers_kwargs),
        telemetry=api.TelemetrySpec(trace_mode=trace_mode,
                                    metrics_sink=metrics_sink,
                                    sink_interval=sink_interval),
        cluster=api.ClusterSpec(num_replicas=len(engines),
                                router=router,
                                router_kwargs=router_kwargs,
                                autoscaler=autoscaler,
                                autoscaler_kwargs=autoscaler_kwargs,
                                max_batch=max_batch,
                                pools=(tuple(pools) if pools is not None
                                       else None)))
    return api.run(spec)
