"""Live-engine fleet backend: ``serve_cluster``.

Serves a fleet of :class:`~repro.serving.ServingEngine` replicas —
real JAX execution, measured wall-clock stage times, per-replica
EMA estimates and EMA/hysteresis detectors — behind one routed arrival
queue.  Each engine keeps its own scheduler runtime and online
block-time estimates (that *is* the replica's identity); the jitted
pipeline executor can be shared across engines
(``ServingEngine(..., executor=shared)``) since replicas serve the
same model.

Replica-scoped interference is injected exactly like single-engine
serving: one slowdown schedule per replica
(``schedules[r](local_q) -> per-EP factors``), so "interference hits
only replica 2" is simply a schedule that slows replica 2's EPs while
the others return all-ones.

Queries execute sequentially on this host (the replicas emulate a
fleet the way the single engine emulates co-located tenants), but the
arrival/queueing ledger is per replica in the workload's wall-clock
units — the same convention ``ServingEngine.serve`` uses for open-loop
runs.
"""
from __future__ import annotations

from typing import Callable, List, Optional, Sequence, Union

from repro.cluster.cluster import Replica, run_cluster
from repro.cluster.trace import ClusterTrace
from repro.workloads.base import Workload


def serve_cluster(engines: Sequence,
                  queries: Sequence,
                  schedules: Union[Callable, Sequence[Callable]],
                  workload: Union[str, Workload, None] = "closed",
                  workload_kwargs: Optional[dict] = None,
                  router: Union[str, object, None] = "round_robin",
                  router_kwargs: Optional[dict] = None,
                  admission: Union[str, object, None] = None,
                  admission_kwargs: Optional[dict] = None,
                  autoscaler: Union[str, object, None] = None,
                  autoscaler_kwargs: Optional[dict] = None,
                  max_batch: int = 1,
                  trace_mode: str = "dense",
                  metrics_sink=None,
                  sink_interval: Optional[int] = None) -> ClusterTrace:
    """Serve fleet ``queries`` through N live engines behind a router.

    ``engines`` — one :class:`~repro.serving.ServingEngine` per
    replica (each owns its runtime/detector/estimates).  ``schedules``
    — per-replica slowdown schedule ``(local_q) -> per-EP factors``, or
    one callable applied to every replica.  The returned trace's
    per-replica peak references are stamped from each engine's online
    clean estimates after the run (NaN for replicas that never served
    a query).

    ``admission`` / ``autoscaler`` select the SLO control plane
    (:mod:`repro.control`, docs/CONTROL.md), identically to
    :func:`~repro.cluster.simulate_cluster` — SLOs are in wall-clock
    seconds here.  Shed queries never touch an engine.

    ``max_batch > 1`` opts into fleet rebatching (docs/CLUSTER.md):
    same-replica routing streaks of open-loop arrivals stack through
    each engine's ``run_batch`` (one set of stage dispatches per
    streak) instead of executing query-by-query.
    """
    if len(engines) < 1:
        raise ValueError("serve_cluster needs at least one engine")
    if callable(schedules):
        schedules = [schedules] * len(engines)
    if len(schedules) != len(engines):
        raise ValueError(f"{len(engines)} engines but "
                         f"{len(schedules)} slowdown schedules")

    replicas = []
    for eng, schedule in zip(engines, schedules):
        local_queries: List = []
        executor = eng.query_executor(local_queries, schedule,
                                      max_batch=max_batch)

        def on_assign(fleet_q, local_q, arrival, _lq=local_queries):
            _lq.append(queries[fleet_q])

        replicas.append(Replica(executor=executor, runtime=eng.runtime,
                                on_assign=on_assign))

    trace = run_cluster(replicas, len(queries), workload=workload,
                        workload_kwargs=workload_kwargs, router=router,
                        router_kwargs=router_kwargs,
                        scheduler_name=getattr(engines[0], "scheduler", ""),
                        admission=admission,
                        admission_kwargs=admission_kwargs,
                        autoscaler=autoscaler,
                        autoscaler_kwargs=autoscaler_kwargs,
                        max_batch=max_batch,
                        trace_mode=trace_mode, metrics_sink=metrics_sink,
                        sink_interval=sink_interval)
    # Peak references only exist after measurement — stamp post-hoc,
    # exactly like ServingEngine.serve does for a single pipeline.
    for rep_trace, eng in zip(trace.replicas, engines):
        rep_trace.peak_throughput = eng.estimated_peak_throughput()
    return trace
