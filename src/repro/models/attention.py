"""GQA attention: flash-style chunked prefill/train path + decode path.

The train/prefill path is a pure-jnp flash-attention (two-level chunked
online softmax).  This keeps activation memory O(S · chunk) instead of
O(S²) — essential for the 32k prefill dry-runs — and doubles as a second
oracle for the Pallas kernel in ``repro.kernels.flash_attention``.

Supports: GQA (num_kv_heads < num_heads), causal masking, sliding-window
attention (Mixtral-style), encoder (bidirectional) mode, qk-norm (Qwen3),
QKV bias (Qwen2).
"""
from __future__ import annotations

from typing import Optional

import jax
import jax.numpy as jnp

from repro.configs.base import ModelConfig
from repro.models.layers import apply_rope, rms_norm

NEG_INF = -1e30


# ---------------------------------------------------------------------------
# Parameters
# ---------------------------------------------------------------------------


def init_attention(rng, cfg: ModelConfig, dtype=jnp.float32):
    d, h = cfg.d_model, cfg.head_dim
    nq, nkv = cfg.num_heads, cfg.num_kv_heads
    ks = jax.random.split(rng, 4)
    s = d ** -0.5
    p = {
        "wq": (jax.random.normal(ks[0], (d, nq * h)) * s).astype(dtype),
        "wk": (jax.random.normal(ks[1], (d, nkv * h)) * s).astype(dtype),
        "wv": (jax.random.normal(ks[2], (d, nkv * h)) * s).astype(dtype),
        "wo": (jax.random.normal(ks[3], (nq * h, d)) * ((nq * h) ** -0.5)
               ).astype(dtype),
    }
    if cfg.qkv_bias:
        p["bq"] = jnp.zeros((nq * h,), dtype)
        p["bk"] = jnp.zeros((nkv * h,), dtype)
        p["bv"] = jnp.zeros((nkv * h,), dtype)
    if cfg.qk_norm:
        p["q_norm"] = jnp.ones((h,), dtype)
        p["k_norm"] = jnp.ones((h,), dtype)
    return p


def _project_qkv(params, cfg: ModelConfig, x: jnp.ndarray,
                 positions: jnp.ndarray):
    """x: [B, S, d] -> q [B,S,nq,h], k/v [B,S,nkv,h] (roped, normed)."""
    B, S, _ = x.shape
    h = cfg.head_dim
    q = jnp.einsum("bsd,dk->bsk", x, params["wq"])
    k = jnp.einsum("bsd,dk->bsk", x, params["wk"])
    v = jnp.einsum("bsd,dk->bsk", x, params["wv"])
    if cfg.qkv_bias:
        q = q + params["bq"]
        k = k + params["bk"]
        v = v + params["bv"]
    q = q.reshape(B, S, cfg.num_heads, h)
    k = k.reshape(B, S, cfg.num_kv_heads, h)
    v = v.reshape(B, S, cfg.num_kv_heads, h)
    if cfg.qk_norm:
        q = rms_norm(q, params["q_norm"], cfg.rms_eps)
        k = rms_norm(k, params["k_norm"], cfg.rms_eps)
    q = apply_rope(q, positions, cfg.rope_theta)
    k = apply_rope(k, positions, cfg.rope_theta)
    return q, k, v


# ---------------------------------------------------------------------------
# Flash-style chunked attention (train / prefill)
# ---------------------------------------------------------------------------


def flash_attention_jnp(q: jnp.ndarray, k: jnp.ndarray, v: jnp.ndarray,
                        *, causal: bool = True,
                        window: Optional[int] = None,
                        chunk_q: int = 512, chunk_k: int = 512) -> jnp.ndarray:
    """Online-softmax attention.

    q: [B, S, Hq, D]; k, v: [B, S, Hkv, D] with Hq % Hkv == 0.
    Returns [B, S, Hq, D].  S must be divisible by the chunk sizes (the
    callers pad); masking is by absolute position (causal and/or sliding
    window of size ``window``: query i attends to keys in
    (i - window, i]).
    """
    B, S, Hq, D = q.shape
    Hkv = k.shape[2]
    group = Hq // Hkv
    scale = D ** -0.5
    nq, nk = S // chunk_q, S // chunk_k

    # [B, Hkv, group, nq, cq, D]
    qg = q.reshape(B, nq, chunk_q, Hkv, group, D).transpose(0, 3, 4, 1, 2, 5)
    kg = k.reshape(B, nk, chunk_k, Hkv, D).transpose(0, 3, 1, 2, 4)
    vg = v.reshape(B, nk, chunk_k, Hkv, D).transpose(0, 3, 1, 2, 4)

    q_pos = jnp.arange(S).reshape(nq, chunk_q)
    k_pos = jnp.arange(S).reshape(nk, chunk_k)

    kg_t = kg.transpose(2, 0, 1, 3, 4)  # [nk, B, Hkv, ck, D]
    vg_t = vg.transpose(2, 0, 1, 3, 4)

    def per_qchunk(args):
        qp, qc = args  # qp: [cq] absolute positions; qc: [B,Hkv,group,cq,D]
        m0 = jnp.full(qc.shape[:-1], NEG_INF, jnp.float32)
        l0 = jnp.zeros(qc.shape[:-1], jnp.float32)
        a0 = jnp.zeros(qc.shape, jnp.float32)

        def body(carry, inp):
            m, l, acc = carry
            kc, vc, kp = inp  # [B,Hkv,ck,D], [B,Hkv,ck,D], [ck]
            s = jnp.einsum("bhgqd,bhkd->bhgqk", qc.astype(jnp.float32),
                           kc.astype(jnp.float32)) * scale
            mask = jnp.ones((chunk_q, chunk_k), bool)
            if causal:
                mask &= qp[:, None] >= kp[None, :]
            if window is not None:
                mask &= qp[:, None] - kp[None, :] < window
            s = jnp.where(mask, s, NEG_INF)
            m_new = jnp.maximum(m, jnp.max(s, axis=-1))
            p = jnp.exp(s - m_new[..., None])
            alpha = jnp.exp(m - m_new)
            l_new = l * alpha + jnp.sum(p, axis=-1)
            acc_new = acc * alpha[..., None] + jnp.einsum(
                "bhgqk,bhkd->bhgqd", p, vc.astype(jnp.float32))
            return (m_new, l_new, acc_new), None

        (m, l, acc), _ = jax.lax.scan(body, (m0, l0, a0), (kg_t, vg_t, k_pos))
        return acc / jnp.maximum(l[..., None], 1e-30)

    qg_t = qg.transpose(3, 0, 1, 2, 4, 5)  # [nq, B, Hkv, group, cq, D]
    out = jax.lax.map(per_qchunk, (q_pos, qg_t))  # [nq, B, Hkv, group, cq, D]
    out = out.transpose(1, 0, 4, 2, 3, 5).reshape(B, S, Hq, D)
    return out.astype(q.dtype)


def _pick_chunk(S: int, preferred: int = 512) -> int:
    c = min(preferred, S)
    while S % c:
        c //= 2
    return max(c, 1)


def attention_forward(params, cfg: ModelConfig, x: jnp.ndarray,
                      positions: jnp.ndarray) -> jnp.ndarray:
    """Full-sequence (train / prefill) attention over x: [B, S, d]."""
    B, S, _ = x.shape
    q, k, v = _project_qkv(params, cfg, x, positions)
    c = _pick_chunk(S)
    out = flash_attention_jnp(q, k, v, causal=cfg.causal,
                              window=cfg.sliding_window,
                              chunk_q=c, chunk_k=c)
    out = out.reshape(B, S, cfg.num_heads * cfg.head_dim)
    return jnp.einsum("bsk,kd->bsd", out, params["wo"])


# ---------------------------------------------------------------------------
# Decode path (single new token against a KV cache)
# ---------------------------------------------------------------------------


def init_kv_cache(cfg: ModelConfig, batch: int, max_len: int, dtype):
    h = cfg.head_dim
    return {
        "k": jnp.zeros((batch, max_len, cfg.num_kv_heads, h), dtype),
        "v": jnp.zeros((batch, max_len, cfg.num_kv_heads, h), dtype),
    }


def attention_decode(params, cfg: ModelConfig, x: jnp.ndarray,
                     cache: dict, index: jnp.ndarray):
    """x: [B, 1, d]; index: scalar position of the new token.

    Returns (out [B, 1, d], updated cache).  The sliding-window variant
    only attends to the last ``window`` cache slots by masking (the cache
    retains max_len slots; ring-buffer compaction is a serving-layer
    optimization, see pipeline/).
    """
    B = x.shape[0]
    positions = jnp.full((B, 1), index, jnp.int32)
    q, k_new, v_new = _project_qkv(params, cfg, x, positions)

    k = jax.lax.dynamic_update_slice(cache["k"], k_new.astype(cache["k"].dtype),
                                     (0, index, 0, 0))
    v = jax.lax.dynamic_update_slice(cache["v"], v_new.astype(cache["v"].dtype),
                                     (0, index, 0, 0))
    S = k.shape[1]
    Hq, Hkv = cfg.num_heads, cfg.num_kv_heads
    group = Hq // Hkv
    qg = q.reshape(B, 1, Hkv, group, cfg.head_dim)
    s = jnp.einsum("bqhgd,bkhd->bhgqk", qg.astype(jnp.float32),
                   k.astype(jnp.float32)) * (cfg.head_dim ** -0.5)
    kp = jnp.arange(S)
    mask = kp <= index
    if cfg.sliding_window is not None:
        mask &= kp > index - cfg.sliding_window
    s = jnp.where(mask[None, None, None, None, :], s, NEG_INF)
    p = jax.nn.softmax(s, axis=-1)
    out = jnp.einsum("bhgqk,bkhd->bqhgd", p, v.astype(jnp.float32))
    out = out.reshape(B, 1, Hq * cfg.head_dim).astype(x.dtype)
    out = jnp.einsum("bsk,kd->bsd", out, params["wo"])
    return out, {"k": k, "v": v}
