"""Block (pipeline-unit) definitions.

A *block* is the homogeneous super-layer the pipeline scheduler moves
between stages (DESIGN.md §4): dense/moe/vlm/audio → one attention
sublayer; ssm → one Mamba2 sublayer; hybrid (Jamba) → the period-8
super-block (1 attn + 7 mamba), MoE on alternating sublayers.

Every sublayer is pre-norm:  x += Mixer(LN(x));  x += FFN(LN(x)).
Blocks expose three modes:

* ``block_forward``   — full sequence (train / encoder / prefill compute)
* ``block_prefill``   — full sequence + returns the decode cache
* ``block_decode``    — one token + cache -> one token + cache

Parameters of all blocks of a model are *stacked* along a leading
``num_blocks`` axis so the assignment of blocks to pipeline stages can be
a runtime argument (recompile-free rebalancing, DESIGN.md §2).
"""
from __future__ import annotations

from typing import Dict, Tuple

import jax
import jax.numpy as jnp

from repro.configs.base import ModelConfig
from repro.models import attention as attn_lib
from repro.models import mamba2 as mamba_lib
from repro.models import moe as moe_lib
from repro.models.sharding_ctx import constrain
from repro.models.layers import init_mlp, init_rms_norm, mlp, rms_norm

ZERO_STATS = dict(aux_loss=0.0, router_z=0.0, dropped_frac=0.0)


def _sublayer_kinds(cfg: ModelConfig):
    """[(mixer_kind, ffn_kind)] per sublayer of one block."""
    out = []
    for i, mixer in enumerate(cfg.layer_pattern):
        if cfg.family == "ssm":
            ffn = "none"
        elif cfg.moe is not None and cfg.sublayer_is_moe(i):
            ffn = "moe"
        elif cfg.d_ff > 0:
            ffn = "dense"
        else:
            ffn = "none"
        out.append((mixer, ffn))
    return out


# ---------------------------------------------------------------------------
# Init
# ---------------------------------------------------------------------------


def init_block(rng, cfg: ModelConfig, dtype=jnp.float32) -> Dict:
    params = {}
    kinds = _sublayer_kinds(cfg)
    rngs = jax.random.split(rng, 2 * len(kinds))
    for i, (mixer, ffn) in enumerate(kinds):
        sub = {"ln1": init_rms_norm(cfg.d_model, dtype)}
        if mixer == "attn":
            sub["mixer"] = attn_lib.init_attention(rngs[2 * i], cfg, dtype)
        else:
            sub["mixer"] = mamba_lib.init_mamba(rngs[2 * i], cfg, dtype)
        if ffn != "none":
            sub["ln2"] = init_rms_norm(cfg.d_model, dtype)
            if ffn == "moe":
                sub["ffn"] = moe_lib.init_moe(rngs[2 * i + 1], cfg.d_model,
                                              cfg.moe, dtype)
            else:
                sub["ffn"] = init_mlp(rngs[2 * i + 1], cfg.d_model, cfg.d_ff,
                                      dtype)
        params[f"sub{i}"] = sub
    return params


def init_stacked_blocks(rng, cfg: ModelConfig, dtype=jnp.float32) -> Dict:
    rngs = jax.random.split(rng, cfg.num_blocks)
    return jax.vmap(lambda r: init_block(r, cfg, dtype))(rngs)


# ---------------------------------------------------------------------------
# Forward modes
# ---------------------------------------------------------------------------


def _apply_ffn(sub, cfg: ModelConfig, ffn_kind: str, x):
    """Returns (delta, stats)."""
    if ffn_kind == "none":
        return None, ZERO_STATS
    h = rms_norm(x, sub["ln2"]["scale"], cfg.rms_eps)
    if ffn_kind == "moe":
        y, st = moe_lib.moe_forward(sub["ffn"], cfg.moe, h)
        return y, dict(aux_loss=st.aux_loss, router_z=st.router_z,
                       dropped_frac=st.dropped_frac)
    return mlp(sub["ffn"], h), ZERO_STATS


def block_forward(params, cfg: ModelConfig, x: jnp.ndarray,
                  positions: jnp.ndarray) -> Tuple[jnp.ndarray, Dict]:
    """Full-sequence block application; returns (x, summed router stats)."""
    stats = dict(ZERO_STATS)
    x = constrain(x)
    for i, (mixer, ffn) in enumerate(_sublayer_kinds(cfg)):
        sub = params[f"sub{i}"]
        h = rms_norm(x, sub["ln1"]["scale"], cfg.rms_eps)
        if mixer == "attn":
            x = x + attn_lib.attention_forward(sub["mixer"], cfg, h, positions)
        else:
            x = x + mamba_lib.mamba_forward(sub["mixer"], cfg, h)
        delta, st = _apply_ffn(sub, cfg, ffn, x)
        if delta is not None:
            x = x + delta
        stats = {k: stats[k] + st[k] for k in stats}
    return x, stats


# -- caches -------------------------------------------------------------------


def init_block_cache(cfg: ModelConfig, batch: int, max_len: int, dtype) -> Dict:
    cache = {}
    for i, (mixer, _) in enumerate(_sublayer_kinds(cfg)):
        if mixer == "attn":
            cache[f"sub{i}"] = attn_lib.init_kv_cache(cfg, batch, max_len, dtype)
        else:
            cache[f"sub{i}"] = mamba_lib.init_mamba_cache(cfg, batch, dtype)
    return cache


def init_stacked_cache(cfg: ModelConfig, batch: int, max_len: int,
                       dtype) -> Dict:
    one = init_block_cache(cfg, batch, max_len, dtype)
    return jax.tree.map(
        lambda a: jnp.zeros((cfg.num_blocks,) + a.shape, a.dtype), one)


def block_prefill(params, cfg: ModelConfig, x: jnp.ndarray,
                  positions: jnp.ndarray, cache: Dict
                  ) -> Tuple[jnp.ndarray, Dict]:
    """Full-sequence forward that also fills this block's decode cache."""
    S = x.shape[1]
    new_cache = {}
    for i, (mixer, ffn) in enumerate(_sublayer_kinds(cfg)):
        sub = params[f"sub{i}"]
        h = rms_norm(x, sub["ln1"]["scale"], cfg.rms_eps)
        if mixer == "attn":
            q, k, v = attn_lib._project_qkv(sub["mixer"], cfg, h, positions)
            c = attn_lib._pick_chunk(S)
            o = attn_lib.flash_attention_jnp(
                q, k, v, causal=cfg.causal, window=cfg.sliding_window,
                chunk_q=c, chunk_k=c)
            o = o.reshape(x.shape[0], S, cfg.num_heads * cfg.head_dim)
            x = x + jnp.einsum("bsk,kd->bsd", o, sub["mixer"]["wo"])
            kc = cache[f"sub{i}"]
            new_cache[f"sub{i}"] = {
                "k": jax.lax.dynamic_update_slice(
                    kc["k"], k.astype(kc["k"].dtype), (0, 0, 0, 0)),
                "v": jax.lax.dynamic_update_slice(
                    kc["v"], v.astype(kc["v"].dtype), (0, 0, 0, 0)),
            }
        else:
            o, mc = mamba_prefill(sub["mixer"], cfg, h)
            x = x + o
            kc = cache[f"sub{i}"]
            new_cache[f"sub{i}"] = {
                "conv": mc["conv"].astype(kc["conv"].dtype),
                "ssm": mc["ssm"].astype(kc["ssm"].dtype),
            }
        delta, _ = _apply_ffn(sub, cfg, ffn, x)
        if delta is not None:
            x = x + delta
    return x, new_cache


def block_decode(params, cfg: ModelConfig, x: jnp.ndarray,
                 cache: Dict, index: jnp.ndarray) -> Tuple[jnp.ndarray, Dict]:
    """One-token decode through one block."""
    new_cache = {}
    for i, (mixer, ffn) in enumerate(_sublayer_kinds(cfg)):
        sub = params[f"sub{i}"]
        h = rms_norm(x, sub["ln1"]["scale"], cfg.rms_eps)
        if mixer == "attn":
            o, new_cache[f"sub{i}"] = attn_lib.attention_decode(
                sub["mixer"], cfg, h, cache[f"sub{i}"], index)
        else:
            o, new_cache[f"sub{i}"] = mamba_lib.mamba_decode(
                sub["mixer"], cfg, h, cache[f"sub{i}"])
        x = x + o
        delta, _ = _apply_ffn(sub, cfg, ffn, x)
        if delta is not None:
            x = x + delta
    return x, new_cache


# ---------------------------------------------------------------------------
# Mamba prefill helper (forward + cache extraction)
# ---------------------------------------------------------------------------


def mamba_prefill(params, cfg: ModelConfig, x: jnp.ndarray):
    """Like mamba_forward but also returns the decode cache."""
    s = cfg.ssm
    B_, S, d = x.shape
    din = s.d_inner(d)
    N = s.d_state
    H = s.num_heads(d)
    P = s.head_dim

    z, xBC_pre, dt = mamba_lib._project(params, x)
    xBC = jax.nn.silu(mamba_lib._causal_conv(
        xBC_pre, params["conv_w"], params["conv_b"]))
    xs = xBC[..., :din].reshape(B_, S, H, P)
    Bm = xBC[..., din:din + N]
    Cm = xBC[..., din + N:]
    dtv = jax.nn.softplus(dt.astype(jnp.float32)
                          + params["dt_bias"]).astype(x.dtype)
    A = -jnp.exp(params["A_log"]).astype(x.dtype)
    chunk = min(s.chunk_size, S)
    while S % chunk:
        chunk //= 2
    y, final_state = mamba_lib.ssd_chunked(xs, dtv, A, Bm, Cm, chunk=chunk)
    y = y + xs * params["D"].astype(x.dtype)[None, None, :, None]
    y = y.reshape(B_, S, din)
    y = rms_norm(y * jax.nn.silu(z), params["norm_scale"], cfg.rms_eps)
    out = jnp.einsum("bsk,kd->bsd", y, params["out_proj"])
    # conv cache = last (d_conv - 1) pre-activation conv inputs
    K = s.d_conv
    conv_cache = xBC_pre[:, S - (K - 1):, :] if S >= K - 1 else \
        jnp.pad(xBC_pre, ((0, 0), (K - 1 - S, 0), (0, 0)))
    return out, {"conv": conv_cache, "ssm": final_state}
