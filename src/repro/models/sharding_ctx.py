"""Optional activation-sharding constraints for the block stack.

XLA SPMD occasionally drops the batch sharding of cotangents at remat /
loop boundaries and falls back to replicating activations (observed:
84 GiB/chip of backward all-gathers on deepseek-moe train, §Perf
iteration 4).  Setting an explicit PartitionSpec here pins activations
(and therefore their cotangents) to the intended sharding at every block
entry — the standard MaxText-style mitigation.

The constraint is a process-global config (set by the launcher around
lower()/compile(), never by library code) so the model code stays
mesh-agnostic when unset.
"""
from __future__ import annotations

from typing import Optional

import jax

_ACTIVATION_SPEC: Optional[jax.sharding.PartitionSpec] = None


def set_activation_spec(spec) -> None:
    global _ACTIVATION_SPEC
    _ACTIVATION_SPEC = spec


def constrain(x):
    """Apply the configured constraint to a [B, S, d] activation."""
    if _ACTIVATION_SPEC is None:
        return x
    return jax.lax.with_sharding_constraint(x, _ACTIVATION_SPEC)
