"""Model assembly: embeddings + stacked blocks (lax.scan) + head.

Public API (all functions jit-able, params are plain pytrees):

* ``init_params(rng, dtype)``
* ``forward(params, tokens|embeds)``            -> logits    (train path)
* ``loss(params, batch)``                       -> (scalar, metrics)
* ``init_cache(batch, max_len, dtype)``
* ``prefill(params, tokens|embeds, cache)``     -> (last logits, cache)
* ``decode_step(params, tokens, cache, index)`` -> (logits, cache)

Blocks are scanned over a stacked [num_blocks, ...] parameter pytree —
the same representation the pipeline runtime slices per stage with
dynamic boundaries.
"""
from __future__ import annotations

import functools
from typing import Dict, Optional, Tuple

import jax
import jax.numpy as jnp

from repro.configs.base import ModelConfig
from repro.models import blocks as blk
from repro.models.layers import (
    cross_entropy,
    embed,
    init_embedding,
    init_rms_norm,
    init_unembed,
    rms_norm,
    unembed,
)


class Model:
    def __init__(self, cfg: ModelConfig, remat: bool = False,
                 unroll_blocks: bool = False):
        """``unroll_blocks``: python-loop over blocks instead of lax.scan.

        Used by the dry-run so per-block collectives/FLOPs appear
        ``num_blocks`` times in the HLO — XLA's cost analysis counts a
        while-loop body exactly once (verified), which would otherwise
        undercount everything inside the scan by L×.
        """
        self.cfg = cfg
        self.remat = remat
        self.unroll_blocks = unroll_blocks

    # -- init ----------------------------------------------------------------
    def init_params(self, rng, dtype=jnp.float32) -> Dict:
        cfg = self.cfg
        k_embed, k_blocks, k_head = jax.random.split(rng, 3)
        params = {
            "blocks": blk.init_stacked_blocks(k_blocks, cfg, dtype),
            "final_norm": init_rms_norm(cfg.d_model, dtype),
            "head": init_unembed(k_head, cfg.d_model, cfg.vocab_size, dtype),
        }
        # Even embedding-input models (VLM) keep a token table for decode.
        params["embed"] = init_embedding(k_embed, cfg.vocab_size,
                                         cfg.d_model, dtype)
        return params

    # -- shared block scan -----------------------------------------------------
    def _scan_blocks(self, params, x, positions):
        cfg = self.cfg

        def body(carry, bp):
            h, stats = carry
            h, st = blk.block_forward(bp, cfg, h, positions)
            stats = {k: stats[k] + st[k] for k in stats}
            return (h, stats), None

        if self.remat:
            body = jax.checkpoint(body)
        stats0 = {k: jnp.zeros((), jnp.float32) for k in blk.ZERO_STATS}
        if self.unroll_blocks:
            carry = (x, stats0)
            for i in range(cfg.num_blocks):
                bp = jax.tree.map(lambda p: p[i], params["blocks"])
                carry, _ = body(carry, bp)
            x, stats = carry
            return x, stats
        (x, stats), _ = jax.lax.scan(body, (x, stats0), params["blocks"])
        return x, stats

    def _embed_in(self, params, tokens: Optional[jnp.ndarray],
                  embeds: Optional[jnp.ndarray]) -> jnp.ndarray:
        if embeds is not None:
            return embeds
        return embed(params["embed"], tokens)

    # -- train / encoder path -----------------------------------------------
    def forward(self, params, tokens: Optional[jnp.ndarray] = None,
                embeds: Optional[jnp.ndarray] = None
                ) -> Tuple[jnp.ndarray, Dict]:
        x = self._embed_in(params, tokens, embeds)
        B, S, _ = x.shape
        positions = jnp.broadcast_to(jnp.arange(S, dtype=jnp.int32), (B, S))
        x, stats = self._scan_blocks(params, x, positions)
        x = rms_norm(x, params["final_norm"]["scale"], self.cfg.rms_eps)
        return unembed(params["head"], x), stats

    def loss(self, params, batch: Dict) -> Tuple[jnp.ndarray, Dict]:
        """batch: {tokens|embeds, labels, [mask]}."""
        logits, stats = self.forward(
            params, batch.get("tokens"), batch.get("embeds"))
        ce = cross_entropy(logits, batch["labels"], batch.get("mask"))
        m = self.cfg.moe
        aux_coef = m.router_aux_coef if m is not None else 0.0
        total = ce + aux_coef * stats["aux_loss"] + 1e-4 * stats["router_z"]
        metrics = {"ce": ce, "aux_loss": stats["aux_loss"],
                   "router_z": stats["router_z"],
                   "dropped_frac": stats["dropped_frac"], "loss": total}
        return total, metrics

    # -- decode path -----------------------------------------------------------
    def init_cache(self, batch: int, max_len: int, dtype=jnp.bfloat16) -> Dict:
        return blk.init_stacked_cache(self.cfg, batch, max_len, dtype)

    def prefill(self, params, tokens: Optional[jnp.ndarray] = None,
                embeds: Optional[jnp.ndarray] = None,
                cache: Optional[Dict] = None) -> Tuple[jnp.ndarray, Dict]:
        """Full-sequence pass filling the cache; returns last-pos logits."""
        cfg = self.cfg
        x = self._embed_in(params, tokens, embeds)
        B, S, _ = x.shape
        positions = jnp.broadcast_to(jnp.arange(S, dtype=jnp.int32), (B, S))

        def body(h, bp_cache):
            bp, c = bp_cache
            h, new_c = blk.block_prefill(bp, cfg, h, positions, c)
            return h, new_c

        if self.remat:
            body = jax.checkpoint(body)
        if self.unroll_blocks:
            new_caches = []
            for i in range(cfg.num_blocks):
                bp_c = jax.tree.map(lambda p: p[i], (params["blocks"], cache))
                x, nc = body(x, bp_c)
                new_caches.append(nc)
            new_cache = jax.tree.map(lambda *xs: jnp.stack(xs), *new_caches)
        else:
            x, new_cache = jax.lax.scan(body, x, (params["blocks"], cache))
        x = rms_norm(x[:, -1:], params["final_norm"]["scale"], cfg.rms_eps)
        return unembed(params["head"], x), new_cache

    def decode_step(self, params, tokens: jnp.ndarray, cache: Dict,
                    index: jnp.ndarray) -> Tuple[jnp.ndarray, Dict]:
        """tokens: [B, 1] -> (logits [B, 1, V], updated cache)."""
        cfg = self.cfg
        x = embed(params["embed"], tokens)

        def body(h, bp_cache):
            bp, c = bp_cache
            h, new_c = blk.block_decode(bp, cfg, h, c, index)
            return h, new_c

        if self.unroll_blocks:
            new_caches = []
            for i in range(cfg.num_blocks):
                bp_c = jax.tree.map(lambda p: p[i], (params["blocks"], cache))
                x, nc = body(x, bp_c)
                new_caches.append(nc)
            new_cache = jax.tree.map(lambda *xs: jnp.stack(xs), *new_caches)
        else:
            x, new_cache = jax.lax.scan(body, x, (params["blocks"], cache))
        x = rms_norm(x, params["final_norm"]["scale"], cfg.rms_eps)
        return unembed(params["head"], x), new_cache


# ---------------------------------------------------------------------------
# Step factories (jit-able top-level entry points)
# ---------------------------------------------------------------------------


def make_forward_fn(cfg: ModelConfig, remat: bool = False):
    model = Model(cfg, remat=remat)

    @functools.partial(jax.jit, static_argnums=())
    def fwd(params, batch):
        return model.forward(params, batch.get("tokens"), batch.get("embeds"))

    return fwd
