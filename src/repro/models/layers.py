"""Shared layer primitives (pure JAX, pytree params)."""
from __future__ import annotations

from typing import Optional

import jax
import jax.numpy as jnp


def rms_norm(x: jnp.ndarray, scale: jnp.ndarray, eps: float = 1e-6) -> jnp.ndarray:
    dtype = x.dtype
    x = x.astype(jnp.float32)
    var = jnp.mean(jnp.square(x), axis=-1, keepdims=True)
    x = x * jax.lax.rsqrt(var + eps)
    return (x * scale.astype(jnp.float32)).astype(dtype)


def init_rms_norm(dim: int, dtype=jnp.float32):
    return {"scale": jnp.ones((dim,), dtype)}


# ---------------------------------------------------------------------------
# Rotary position embedding
# ---------------------------------------------------------------------------


def rope_frequencies(head_dim: int, theta: float) -> jnp.ndarray:
    return 1.0 / (theta ** (jnp.arange(0, head_dim, 2, dtype=jnp.float32)
                            / head_dim))


def apply_rope(x: jnp.ndarray, positions: jnp.ndarray,
               theta: float) -> jnp.ndarray:
    """x: [..., S, H, D]; positions: broadcastable to [..., S]."""
    d = x.shape[-1]
    freqs = rope_frequencies(d, theta)                      # [D/2]
    angles = positions[..., :, None, None].astype(jnp.float32) * freqs  # [...,S,1,D/2]
    cos, sin = jnp.cos(angles), jnp.sin(angles)
    x1, x2 = jnp.split(x.astype(jnp.float32), 2, axis=-1)
    out = jnp.concatenate([x1 * cos - x2 * sin, x2 * cos + x1 * sin], axis=-1)
    return out.astype(x.dtype)


# ---------------------------------------------------------------------------
# SwiGLU MLP
# ---------------------------------------------------------------------------


def init_mlp(rng, d_model: int, d_ff: int, dtype=jnp.float32):
    k1, k2, k3 = jax.random.split(rng, 3)
    s_in = d_model ** -0.5
    s_out = d_ff ** -0.5
    return {
        "wi": (jax.random.normal(k1, (d_model, d_ff)) * s_in).astype(dtype),
        "wg": (jax.random.normal(k2, (d_model, d_ff)) * s_in).astype(dtype),
        "wo": (jax.random.normal(k3, (d_ff, d_model)) * s_out).astype(dtype),
    }


def mlp(params, x: jnp.ndarray) -> jnp.ndarray:
    h = jnp.einsum("...d,df->...f", x, params["wi"])
    g = jnp.einsum("...d,df->...f", x, params["wg"])
    h = jax.nn.silu(g) * h
    return jnp.einsum("...f,fd->...d", h, params["wo"])


# ---------------------------------------------------------------------------
# Embedding / unembedding
# ---------------------------------------------------------------------------


def init_embedding(rng, vocab: int, d_model: int, dtype=jnp.float32):
    return {"table": (jax.random.normal(rng, (vocab, d_model))
                      * (d_model ** -0.5)).astype(dtype)}


def embed(params, tokens: jnp.ndarray) -> jnp.ndarray:
    return jnp.take(params["table"], tokens, axis=0)


def init_unembed(rng, d_model: int, vocab: int, dtype=jnp.float32):
    return {"w": (jax.random.normal(rng, (d_model, vocab))
                  * (d_model ** -0.5)).astype(dtype)}


def unembed(params, x: jnp.ndarray) -> jnp.ndarray:
    return jnp.einsum("...d,dv->...v", x, params["w"])


def cross_entropy(logits: jnp.ndarray, labels: jnp.ndarray,
                  mask: Optional[jnp.ndarray] = None) -> jnp.ndarray:
    """Mean token cross-entropy in fp32."""
    logits = logits.astype(jnp.float32)
    logz = jax.nn.logsumexp(logits, axis=-1)
    ll = jnp.take_along_axis(logits, labels[..., None], axis=-1)[..., 0]
    nll = logz - ll
    if mask is not None:
        mask = mask.astype(jnp.float32)
        return jnp.sum(nll * mask) / jnp.maximum(jnp.sum(mask), 1.0)
    return jnp.mean(nll)
