"""Mixture-of-Experts FFN with top-k routing and capacity-buffer dispatch.

Dispatch is grouped and scatter-based (t5x-style groups = batch rows, so
group-local token counts stay small and the cumulative slot assignment
never crosses a data shard):  every (token, choice) pair claims a slot in
a per-expert capacity buffer via a cumulative count; overflowing tokens
are dropped for that expert (standard capacity-factor semantics).  Memory
is O(B·E·C·d) with C = S·k·cf/E, instead of the O(T·E·C) one-hot dispatch
tensor — the difference between ~10^8 and ~10^11 elements for
deepseek-moe's 64-expert/top-6 router at 4k tokens per row.

Supports DeepSeek-style *shared experts* (always-on, fused into one wide
SwiGLU) next to the routed experts.  Returns the routing statistics needed
for the load-balance auxiliary loss (Switch-style).
"""
from __future__ import annotations

import dataclasses
from typing import Tuple

import jax
import jax.numpy as jnp

from repro.configs.base import MoEConfig
from repro.models.layers import init_mlp, mlp


def init_moe(rng, d_model: int, m: MoEConfig, dtype=jnp.float32):
    ks = jax.random.split(rng, 5)
    s_in = d_model ** -0.5
    s_out = m.d_expert ** -0.5
    E = m.num_experts
    p = {
        "router": (jax.random.normal(ks[0], (d_model, E)) * s_in
                   ).astype(jnp.float32),  # router math stays fp32
        "wi": (jax.random.normal(ks[1], (E, d_model, m.d_expert)) * s_in
               ).astype(dtype),
        "wg": (jax.random.normal(ks[2], (E, d_model, m.d_expert)) * s_in
               ).astype(dtype),
        "wo": (jax.random.normal(ks[3], (E, m.d_expert, d_model)) * s_out
               ).astype(dtype),
    }
    if m.num_shared_experts:
        p["shared"] = init_mlp(ks[4], d_model,
                               m.num_shared_experts * m.d_shared, dtype)
    return p


@dataclasses.dataclass
class RouterStats:
    """Per-call routing statistics (fp32 scalars)."""
    aux_loss: jnp.ndarray       # Switch load-balance loss
    router_z: jnp.ndarray       # mean squared logsumexp (z-loss term)
    dropped_frac: jnp.ndarray   # fraction of (token, choice) pairs dropped


def capacity_per_group(group_tokens: int, m: MoEConfig) -> int:
    c = int(group_tokens * m.num_experts_per_tok * m.capacity_factor
            / m.num_experts)
    # round up to an MXU-friendly multiple of 8 and keep >= 4
    return max(4, -(-c // 8) * 8)


def _route_group(xf: jnp.ndarray, router: jnp.ndarray, m: MoEConfig, C: int):
    """One group's routing -> dispatch/combine tensors.

    xf: [T, d].  Returns dispatch [T, E, C] (0/1), combine [T, E, C]
    (gate-weighted) and the aux statistics.  Everything downstream is an
    einsum — no scatter/gather, which XLA's SPMD partitioner handles
    without replicating operands (§Perf iteration 3: the scatter-based
    dispatch cost 105 GiB/chip of involuntary all-gathers per train step
    on deepseek-moe).
    """
    T = xf.shape[0]
    E, K = m.num_experts, m.num_experts_per_tok
    logits = jnp.einsum("td,de->te", xf.astype(jnp.float32), router)
    probs = jax.nn.softmax(logits, axis=-1)                     # [T, E]
    gate, expert_idx = jax.lax.top_k(probs, K)                  # [T, K]
    gate = gate / jnp.maximum(gate.sum(-1, keepdims=True), 1e-9)

    choice_oh = jax.nn.one_hot(expert_idx, E, dtype=jnp.float32)  # [T,K,E]
    flat_oh = choice_oh.reshape(T * K, E)
    pos_in_expert = jnp.cumsum(flat_oh, axis=0) - flat_oh       # [T*K, E]
    slot = jnp.sum(pos_in_expert * flat_oh, axis=-1).reshape(T, K)
    keep = (slot < C).astype(jnp.float32)                       # [T, K]
    slot_oh = jax.nn.one_hot(slot.clip(0, C - 1), C,
                             dtype=jnp.float32)                 # [T,K,C]
    slot_oh = slot_oh * keep[..., None]
    dispatch = jnp.einsum("tke,tkc->tec", choice_oh, slot_oh)   # [T,E,C]
    combine = jnp.einsum("tke,tkc,tk->tec", choice_oh, slot_oh,
                         gate)                                  # [T,E,C]

    f = jnp.mean(choice_oh.sum(1), axis=0)                      # [E]
    pbar = jnp.mean(probs, axis=0)                              # [E]
    zsum = jnp.mean(jax.nn.logsumexp(logits, axis=-1) ** 2)
    dropped = 1.0 - jnp.mean(keep)
    return dispatch, combine, f, pbar, zsum, dropped


def _group_tokens(total: int, S: int, preferred: int) -> int:
    """Fixed token-group size: bounds the [Tg, E, C] dispatch tensor and
    the capacity variance.  Decode (S=1) degenerates to per-token groups
    (never drops)."""
    tg = min(preferred, S if S > 1 else 1)
    while total % tg:
        tg //= 2
    return max(tg, 1)


def moe_forward(params, m: MoEConfig, x: jnp.ndarray,
                group_size: int = 512) -> Tuple[jnp.ndarray, RouterStats]:
    """x: [B, S, d] -> (y [B, S, d], stats)."""
    B, S, d = x.shape
    E = m.num_experts
    T = B * S
    Tg = _group_tokens(T, S, group_size)
    G = T // Tg
    C = capacity_per_group(Tg, m)
    xg = x.reshape(G, Tg, d)

    route = jax.vmap(lambda g: _route_group(g, params["router"], m, C))
    dispatch, combine, f, pbar, zsum, dropped = route(xg)

    buf = jnp.einsum("gtec,gtd->gecd", dispatch.astype(x.dtype), xg)

    # Expert matmuls batched over groups: [G,E,C,d] x [E,d,f].
    h = jnp.einsum("gecd,edf->gecf", buf, params["wi"])
    g = jnp.einsum("gecd,edf->gecf", buf, params["wg"])
    y = jnp.einsum("gecf,efd->gecd", jax.nn.silu(g) * h, params["wo"])

    out = jnp.einsum("gtec,gecd->gtd", combine.astype(x.dtype), y)
    out = out.reshape(B, S, d)

    if "shared" in params:
        out = out + mlp(params["shared"], x)

    # Switch aux loss over the whole call: E * sum_e mean(f_e)/K * mean(p_e)
    aux = E * jnp.sum(jnp.mean(f, 0) / m.num_experts_per_tok * jnp.mean(pbar, 0))
    return out, RouterStats(aux_loss=aux,
                            router_z=jnp.mean(zsum),
                            dropped_frac=jnp.mean(dropped))
