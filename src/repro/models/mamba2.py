"""Mamba2 block: SSD (state-space duality) chunked forward + recurrent decode.

Follows the discrete SSD formulation of arXiv:2405.21060 (minimal
reference): within a chunk the token mixing is the quadratic dual form
(attention-like, MXU-friendly); across chunks a linear state recurrence
carries [H, P, N] states.  ``n_groups = 1`` (B/C shared across heads).

The chunked scan here is the pure-jnp reference; the Pallas kernel in
``repro.kernels.ssd_scan`` implements the same contraction with explicit
VMEM tiling and is validated against :func:`ssd_chunked`.
"""
from __future__ import annotations

from typing import Tuple

import jax
import jax.numpy as jnp

from repro.configs.base import ModelConfig
from repro.models.layers import rms_norm


# ---------------------------------------------------------------------------
# Core SSD math
# ---------------------------------------------------------------------------


def segsum(x: jnp.ndarray) -> jnp.ndarray:
    """Stable segment-sum: out[..., i, j] = sum_{k=j+1..i} x[..., k]
    (lower-triangular; -inf above the diagonal)."""
    T = x.shape[-1]
    cs = jnp.cumsum(x, axis=-1)
    out = cs[..., :, None] - cs[..., None, :]
    mask = jnp.tril(jnp.ones((T, T), bool), k=0)
    return jnp.where(mask, out, -jnp.inf)


def ssd_chunked(x: jnp.ndarray, dt: jnp.ndarray, A: jnp.ndarray,
                B: jnp.ndarray, C: jnp.ndarray,
                chunk: int = 256,
                init_state: jnp.ndarray | None = None
                ) -> Tuple[jnp.ndarray, jnp.ndarray]:
    """SSD scan.

    x:  [b, S, H, P]   (already multiplied by nothing; dt applied inside)
    dt: [b, S, H]      (post-softplus, > 0)
    A:  [H]            (negative)
    B:  [b, S, N], C: [b, S, N]  (n_groups=1, shared across heads)
    Returns (y [b, S, H, P], final_state [b, H, P, N]).
    """
    b, S, H, P = x.shape
    N = B.shape[-1]
    if S % chunk:
        raise ValueError(f"S={S} not divisible by chunk={chunk}")
    nc = S // chunk

    xb = x.reshape(b, nc, chunk, H, P)
    dtb = dt.reshape(b, nc, chunk, H)
    Bb = B.reshape(b, nc, chunk, N)
    Cb = C.reshape(b, nc, chunk, N)

    dA = dtb * A[None, None, None, :]                  # [b,nc,cs,H]
    dA = jnp.moveaxis(dA, -1, -2)                      # [b,nc,H,cs]
    dA_cs = jnp.cumsum(dA, axis=-1)                    # [b,nc,H,cs]

    # 1. Intra-chunk (diagonal block) output: quadratic dual form.
    L = jnp.exp(segsum(dA))                            # [b,nc,H,cs,cs]
    # scores: C_i · B_j
    cb = jnp.einsum("bcin,bcjn->bcij", Cb, Bb)         # [b,nc,cs,cs]
    xdt = xb * dtb[..., None]                          # [b,nc,cs,H,P]
    y_diag = jnp.einsum("bcij,bchij,bcjhp->bcihp",
                        cb, L, xdt)

    # 2. Chunk states: decayed sum of B ⊗ x within each chunk.
    decay_states = jnp.exp(dA_cs[..., -1:] - dA_cs)    # [b,nc,H,cs]
    states = jnp.einsum("bchl,bcln,bclhp->bchpn",
                        decay_states, Bb, xdt)         # [b,nc,H,P,N]

    # 3. Inter-chunk recurrence.
    chunk_decay = jnp.exp(dA_cs[..., -1])              # [b,nc,H]
    s0 = (init_state if init_state is not None
          else jnp.zeros((b, H, P, N), x.dtype))

    def scan_fn(carry, inp):
        st, dec = inp                                  # [b,H,P,N], [b,H]
        prev = carry
        new = prev * dec[..., None, None] + st
        return new, prev

    (final_state, prev_states) = jax.lax.scan(
        scan_fn, s0.astype(jnp.float32),
        (jnp.moveaxis(states, 1, 0).astype(jnp.float32),
         jnp.moveaxis(chunk_decay, 1, 0).astype(jnp.float32)))
    prev_states = jnp.moveaxis(prev_states, 0, 1)      # [b,nc,H,P,N]

    # 4. Inter-chunk (off-diagonal) output: read previous state.
    state_decay = jnp.exp(dA_cs)                       # [b,nc,H,cs]
    y_off = jnp.einsum("bcln,bchpn,bchl->bclhp",
                       Cb, prev_states.astype(x.dtype), state_decay)

    y = (y_diag + y_off).reshape(b, S, H, P)
    return y, final_state.astype(x.dtype)


def ssd_step(x: jnp.ndarray, dt: jnp.ndarray, A: jnp.ndarray,
             B: jnp.ndarray, C: jnp.ndarray,
             state: jnp.ndarray) -> Tuple[jnp.ndarray, jnp.ndarray]:
    """Single-token recurrence.

    x: [b, H, P]; dt: [b, H]; B, C: [b, N]; state: [b, H, P, N].
    h' = h * exp(dt A) + dt * x ⊗ B ;  y = h' · C
    """
    dA = jnp.exp(dt * A[None, :])                      # [b,H]
    xdt = x * dt[..., None]                            # [b,H,P]
    new_state = (state * dA[..., None, None]
                 + jnp.einsum("bhp,bn->bhpn", xdt, B))
    y = jnp.einsum("bhpn,bn->bhp", new_state, C)
    return y, new_state


# ---------------------------------------------------------------------------
# Full Mamba2 block (projections + conv + SSD + gated norm)
# ---------------------------------------------------------------------------


def init_mamba(rng, cfg: ModelConfig, dtype=jnp.float32):
    """Projections are kept *separate* (wz/wx/wB/wC/wdt) rather than fused
    into one in_proj: a fused projection's z/xBC/dt slice boundaries do
    not align with tensor-parallel shard boundaries, which forces XLA to
    re-gather the SSM state at every sublayer (observed 3.9 GiB/chip on
    the Jamba decode step, §Perf iteration 6).  Separate matmuls have
    identical FLOPs and shard cleanly: wz/wx/wdt on heads/channels, the
    small wB/wC (and their convs) replicated."""
    s = cfg.ssm
    d = cfg.d_model
    din = s.d_inner(d)
    H = s.num_heads(d)
    N = s.d_state
    ks = jax.random.split(rng, 7)
    sc = d ** -0.5
    return {
        "wz": (jax.random.normal(ks[0], (d, din)) * sc).astype(dtype),
        "wx": (jax.random.normal(ks[1], (d, din)) * sc).astype(dtype),
        "wB": (jax.random.normal(ks[2], (d, N)) * sc).astype(dtype),
        "wC": (jax.random.normal(ks[3], (d, N)) * sc).astype(dtype),
        "wdt": (jax.random.normal(ks[4], (d, H)) * sc).astype(dtype),
        "conv_w": (jax.random.normal(ks[5], (s.d_conv, din + 2 * N))
                   * (s.d_conv * (din + 2 * N)) ** -0.5).astype(dtype),
        "conv_b": jnp.zeros((din + 2 * N,), dtype),
        "A_log": jnp.log(jnp.linspace(1.0, 16.0, H)).astype(jnp.float32),
        "D": jnp.ones((H,), jnp.float32),
        "dt_bias": jnp.zeros((H,), jnp.float32),
        "norm_scale": jnp.ones((din,), dtype),
        "out_proj": (jax.random.normal(ks[6], (din, d)) * din ** -0.5
                     ).astype(dtype),
    }


def _project(params, x: jnp.ndarray):
    """x: [B, S, d] -> z, xBC (pre-conv), dt."""
    z = jnp.einsum("bsd,dk->bsk", x, params["wz"])
    xs = jnp.einsum("bsd,dk->bsk", x, params["wx"])
    Bm = jnp.einsum("bsd,dn->bsn", x, params["wB"])
    Cm = jnp.einsum("bsd,dn->bsn", x, params["wC"])
    dt = jnp.einsum("bsd,dh->bsh", x, params["wdt"])
    xBC = jnp.concatenate([xs, Bm, Cm], axis=-1)
    return z, xBC, dt


def _causal_conv(xBC: jnp.ndarray, w: jnp.ndarray, b: jnp.ndarray,
                 init: jnp.ndarray | None = None) -> jnp.ndarray:
    """Depthwise causal conv1d.  xBC: [B, S, Cd]; w: [K, Cd].

    ``init``: [B, K-1, Cd] left-context (decode prefill continuity)."""
    K = w.shape[0]
    if init is None:
        pad = jnp.zeros((xBC.shape[0], K - 1, xBC.shape[-1]), xBC.dtype)
    else:
        pad = init.astype(xBC.dtype)
    xp = jnp.concatenate([pad, xBC], axis=1)          # [B, S+K-1, Cd]
    out = sum(xp[:, i:i + xBC.shape[1]] * w[i] for i in range(K))
    return out + b


def mamba_forward(params, cfg: ModelConfig, x: jnp.ndarray) -> jnp.ndarray:
    """Train/prefill forward.  x: [B, S, d] -> [B, S, d]."""
    s = cfg.ssm
    B_, S, d = x.shape
    din = s.d_inner(d)
    N = s.d_state
    H = s.num_heads(d)
    P = s.head_dim

    z, xBC, dt = _project(params, x)
    xBC = jax.nn.silu(_causal_conv(xBC, params["conv_w"], params["conv_b"]))
    xs = xBC[..., :din].reshape(B_, S, H, P)
    Bm = xBC[..., din:din + N]
    Cm = xBC[..., din + N:]
    dt = jax.nn.softplus(dt.astype(jnp.float32)
                         + params["dt_bias"]).astype(x.dtype)
    A = -jnp.exp(params["A_log"])
    chunk = min(s.chunk_size, S)
    while S % chunk:
        chunk //= 2
    y, _ = ssd_chunked(xs, dt, A.astype(x.dtype), Bm, Cm, chunk=chunk)
    y = y + xs * params["D"].astype(x.dtype)[None, None, :, None]
    y = y.reshape(B_, S, din)
    y = rms_norm(y * jax.nn.silu(z), params["norm_scale"], cfg.rms_eps)
    return jnp.einsum("bsk,kd->bsd", y, params["out_proj"])


# ---------------------------------------------------------------------------
# Decode
# ---------------------------------------------------------------------------


def init_mamba_cache(cfg: ModelConfig, batch: int, dtype):
    s = cfg.ssm
    din = s.d_inner(cfg.d_model)
    H = s.num_heads(cfg.d_model)
    return {
        "conv": jnp.zeros((batch, s.d_conv - 1, din + 2 * s.d_state), dtype),
        "ssm": jnp.zeros((batch, H, s.head_dim, s.d_state), dtype),
    }


def mamba_decode(params, cfg: ModelConfig, x: jnp.ndarray, cache: dict):
    """x: [B, 1, d] -> ([B, 1, d], updated cache)."""
    s = cfg.ssm
    B_, _, d = x.shape
    din = s.d_inner(d)
    N = s.d_state
    H = s.num_heads(d)
    P = s.head_dim

    z, xBC, dt = _project(params, x)
    # conv over the cached window + current token
    window = jnp.concatenate([cache["conv"], xBC], axis=1)  # [B, K, Cd]
    conv_out = (jnp.einsum("bkc,kc->bc", window, params["conv_w"])
                + params["conv_b"])[:, None, :]
    xBC = jax.nn.silu(conv_out)
    new_conv = window[:, 1:]

    xs = xBC[..., :din].reshape(B_, H, P)
    Bm = xBC[:, 0, din:din + N]
    Cm = xBC[:, 0, din + N:]
    dtv = jax.nn.softplus(dt[:, 0].astype(jnp.float32)
                          + params["dt_bias"]).astype(x.dtype)
    A = -jnp.exp(params["A_log"]).astype(x.dtype)
    y, new_ssm = ssd_step(xs, dtv, A, Bm, Cm, cache["ssm"])
    y = y + xs * params["D"].astype(x.dtype)[None, :, None]
    y = y.reshape(B_, 1, din)
    y = rms_norm(y * jax.nn.silu(z), params["norm_scale"], cfg.rms_eps)
    out = jnp.einsum("bsk,kd->bsd", y, params["out_proj"])
    return out, {"conv": new_conv, "ssm": new_ssm.astype(cache["ssm"].dtype)}
