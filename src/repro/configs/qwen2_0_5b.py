"""Qwen2-0.5B — dense decoder, GQA kv=2, QKV bias. [arXiv:2407.10671]"""
import dataclasses

from repro.configs.base import ModelConfig

CONFIG = ModelConfig(
    name="qwen2-0.5b", family="dense",
    num_layers=24, d_model=896, num_heads=14, num_kv_heads=2,
    d_ff=4864, vocab_size=151936, head_dim=64,
    qkv_bias=True, rope_theta=1e6,
    source="arXiv:2407.10671",
)


def smoke_config() -> ModelConfig:
    return dataclasses.replace(
        CONFIG, name="qwen2-0.5b-smoke", num_layers=2, d_model=224,
        num_heads=4, num_kv_heads=2, head_dim=56, d_ff=448, vocab_size=512)
