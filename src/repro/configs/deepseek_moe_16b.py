"""DeepSeekMoE-16B — fine-grained 64 routed top-6 + 2 shared. [arXiv:2401.06066]"""
import dataclasses

from repro.configs.base import ModelConfig, MoEConfig

CONFIG = ModelConfig(
    name="deepseek-moe-16b", family="moe",
    num_layers=28, d_model=2048, num_heads=16, num_kv_heads=16,
    d_ff=1408, vocab_size=102400, head_dim=128,
    rope_theta=1e4,
    moe=MoEConfig(num_experts=64, num_experts_per_tok=6, d_expert=1408,
                  num_shared_experts=2, d_shared=1408),
    source="arXiv:2401.06066",
)


def smoke_config() -> ModelConfig:
    return dataclasses.replace(
        CONFIG, name="deepseek-moe-16b-smoke", num_layers=2, d_model=256,
        num_heads=4, num_kv_heads=4, head_dim=64, d_ff=128, vocab_size=512,
        moe=MoEConfig(num_experts=4, num_experts_per_tok=2, d_expert=128,
                      num_shared_experts=1, d_shared=128))
