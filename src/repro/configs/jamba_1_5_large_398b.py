"""Jamba-1.5-Large 398B — hybrid Mamba+attention 1:7 interleave, MoE 16e top-2.

[arXiv:2403.19887]  Block = period-8 super-block (1 attn + 7 mamba);
MoE replaces the MLP on every other sublayer (offset 1), per the Jamba
paper's e=2 MoE placement.
"""
import dataclasses

from repro.configs.base import ModelConfig, MoEConfig, SSMConfig

CONFIG = ModelConfig(
    name="jamba-1.5-large-398b", family="hybrid",
    num_layers=72, d_model=8192, num_heads=64, num_kv_heads=8,
    d_ff=24576, vocab_size=65536, head_dim=128,
    layer_pattern=("attn",) + ("mamba",) * 7,
    ssm=SSMConfig(d_state=128, d_conv=4, expand=2, head_dim=64),
    moe=MoEConfig(num_experts=16, num_experts_per_tok=2, d_expert=24576,
                  every=2, offset=1),
    source="arXiv:2403.19887",
)


def smoke_config() -> ModelConfig:
    return dataclasses.replace(
        CONFIG, name="jamba-1.5-large-398b-smoke",
        num_layers=8, d_model=256, num_heads=4, num_kv_heads=2, head_dim=64,
        d_ff=512, vocab_size=512,
        ssm=SSMConfig(d_state=32, d_conv=4, expand=2, head_dim=64,
                      chunk_size=32),
        moe=MoEConfig(num_experts=4, num_experts_per_tok=2, d_expert=512,
                      every=2, offset=1))
