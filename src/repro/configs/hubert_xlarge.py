"""HuBERT-XLarge — encoder-only audio backbone; conv feature extractor is a
stub per the carve-out (input_specs provides frame embeddings).
[arXiv:2106.07447]
"""
import dataclasses

from repro.configs.base import ModelConfig

CONFIG = ModelConfig(
    name="hubert-xlarge", family="audio",
    num_layers=48, d_model=1280, num_heads=16, num_kv_heads=16,
    d_ff=5120, vocab_size=504, head_dim=80,
    causal=False, is_decoder=False, embedding_inputs=True,
    source="arXiv:2106.07447",
)


def smoke_config() -> ModelConfig:
    return dataclasses.replace(
        CONFIG, name="hubert-xlarge-smoke", num_layers=2, d_model=256,
        num_heads=4, num_kv_heads=4, head_dim=64, d_ff=512, vocab_size=128)
