"""Qwen3-4B — dense decoder, GQA kv=8, qk_norm. [hf:Qwen/Qwen3-8B]"""
import dataclasses

from repro.configs.base import ModelConfig

CONFIG = ModelConfig(
    name="qwen3-4b", family="dense",
    num_layers=36, d_model=2560, num_heads=32, num_kv_heads=8,
    d_ff=9728, vocab_size=151936, head_dim=128,
    qk_norm=True, rope_theta=1e6,
    source="hf:Qwen/Qwen3-8B (4B sibling per assignment)",
)


def smoke_config() -> ModelConfig:
    return dataclasses.replace(
        CONFIG, name="qwen3-4b-smoke", num_layers=2, d_model=256,
        num_heads=4, num_kv_heads=2, head_dim=64, d_ff=512, vocab_size=512)
