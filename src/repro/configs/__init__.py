from repro.configs.base import (  # noqa: F401
    ARCH_IDS,
    INPUT_SHAPES,
    InputShape,
    ModelConfig,
    MoEConfig,
    SSMConfig,
    get_config,
    get_smoke_config,
    long_context_variant,
    shape_is_applicable,
)
