"""Model / shape configuration system.

Every assigned architecture is expressed as a :class:`ModelConfig`.  The
pipeline unit is a *block* (a homogeneous super-layer) so that stage
boundaries can be dynamic runtime arguments (see DESIGN.md §2/§4).
"""
from __future__ import annotations

import dataclasses
import importlib
from typing import Optional, Tuple

# ---------------------------------------------------------------------------
# Sub-configs
# ---------------------------------------------------------------------------


@dataclasses.dataclass(frozen=True)
class MoEConfig:
    """Mixture-of-experts settings for the FFN sublayer."""

    num_experts: int
    num_experts_per_tok: int
    d_expert: int                 # hidden size of each routed expert
    num_shared_experts: int = 0   # DeepSeek-style always-on experts
    d_shared: int = 0             # hidden size of each shared expert
    capacity_factor: float = 1.25
    router_aux_coef: float = 0.01
    # Apply MoE every `every` blocks starting at `offset` (Jamba: every=2).
    every: int = 1
    offset: int = 0


@dataclasses.dataclass(frozen=True)
class SSMConfig:
    """Mamba2 (SSD) settings."""

    d_state: int = 128
    d_conv: int = 4
    expand: int = 2
    head_dim: int = 64            # SSD head dim (P)
    chunk_size: int = 256

    def d_inner(self, d_model: int) -> int:
        return self.expand * d_model

    def num_heads(self, d_model: int) -> int:
        return self.d_inner(d_model) // self.head_dim


@dataclasses.dataclass(frozen=True)
class ModelConfig:
    """Architecture description.

    ``family`` ∈ {dense, moe, ssm, hybrid, vlm, audio}.  ``layer_pattern``
    describes one *block* as a tuple of sublayer kinds drawn from
    {"attn", "mamba"}; dense/moe/vlm/audio blocks are ("attn",) and the
    Jamba block is ("attn",) + ("mamba",)*7.
    """

    name: str
    family: str
    num_layers: int               # total sublayers, == num_blocks*len(pattern)
    d_model: int
    num_heads: int
    num_kv_heads: int
    d_ff: int
    vocab_size: int
    head_dim: Optional[int] = None          # defaults to d_model // num_heads
    layer_pattern: Tuple[str, ...] = ("attn",)
    moe: Optional[MoEConfig] = None
    ssm: Optional[SSMConfig] = None
    # attention options
    qk_norm: bool = False
    qkv_bias: bool = False
    sliding_window: Optional[int] = None    # None = full attention
    causal: bool = True                     # False for encoder-only (audio)
    rope_theta: float = 1e6
    rms_eps: float = 1e-6
    # modality frontend stub: inputs are precomputed embeddings, not tokens
    embedding_inputs: bool = False
    # has an autoregressive decode step at all
    is_decoder: bool = True
    # provenance
    source: str = ""

    # -- derived -----------------------------------------------------------
    def __post_init__(self):
        if self.head_dim is None:
            object.__setattr__(self, "head_dim", self.d_model // self.num_heads)
        if self.num_layers % len(self.layer_pattern) != 0:
            raise ValueError(
                f"{self.name}: num_layers={self.num_layers} not divisible by "
                f"pattern length {len(self.layer_pattern)}")

    @property
    def num_blocks(self) -> int:
        return self.num_layers // len(self.layer_pattern)

    def block_has_attn(self) -> bool:
        return "attn" in self.layer_pattern

    def block_has_mamba(self) -> bool:
        return "mamba" in self.layer_pattern

    def sublayer_is_moe(self, sublayer_idx: int) -> bool:
        """Whether the FFN of sublayer `sublayer_idx` (within a block) is MoE."""
        if self.moe is None:
            return False
        return sublayer_idx % self.moe.every == self.moe.offset

    # Rough parameter counts (used for roofline MODEL_FLOPS and reports).
    def param_count(self) -> int:
        d, h = self.d_model, self.head_dim
        n_q, n_kv = self.num_heads, self.num_kv_heads
        total = self.vocab_size * d  # embed
        if self.is_decoder:
            total += self.vocab_size * d  # unembed (untied)
        per_pattern = 0
        for i, kind in enumerate(self.layer_pattern):
            if kind == "attn":
                per_pattern += d * (n_q * h) + 2 * d * (n_kv * h) + (n_q * h) * d
            else:  # mamba2
                s = self.ssm
                din = s.d_inner(d)
                nh = s.num_heads(d)
                # in_proj produces [z, x, B, C, dt]
                per_pattern += d * (2 * din + 2 * s.d_state + nh) + din * d
                per_pattern += s.d_conv * (din + 2 * s.d_state)
            per_pattern += 2 * d  # norms
            # FFN
            if self.moe is not None and self.sublayer_is_moe(i):
                m = self.moe
                per_pattern += m.num_experts * 3 * d * m.d_expert
                per_pattern += m.num_shared_experts * 3 * d * m.d_shared
                per_pattern += d * m.num_experts  # router
            elif kind == "attn" and self.d_ff > 0 and (
                    self.family not in ("ssm",)):
                per_pattern += 3 * d * self.d_ff
        total += self.num_blocks * per_pattern
        return total

    def active_param_count(self) -> int:
        """Params touched per token (MoE: only routed top-k + shared)."""
        if self.moe is None:
            return self.param_count()
        d = self.d_model
        m = self.moe
        full = self.param_count()
        moe_sublayers = sum(
            1 for i in range(len(self.layer_pattern)) if self.sublayer_is_moe(i))
        inactive = (m.num_experts - m.num_experts_per_tok) * 3 * d * m.d_expert
        return full - self.num_blocks * moe_sublayers * inactive


# ---------------------------------------------------------------------------
# Input shapes (assigned)
# ---------------------------------------------------------------------------


@dataclasses.dataclass(frozen=True)
class InputShape:
    name: str
    seq_len: int
    global_batch: int
    mode: str  # "train" | "prefill" | "decode"


INPUT_SHAPES = {
    "train_4k": InputShape("train_4k", 4096, 256, "train"),
    "prefill_32k": InputShape("prefill_32k", 32768, 32, "prefill"),
    "decode_32k": InputShape("decode_32k", 32768, 128, "decode"),
    "long_500k": InputShape("long_500k", 524288, 1, "decode"),
}


# ---------------------------------------------------------------------------
# Registry
# ---------------------------------------------------------------------------

_ARCH_MODULES = {
    "jamba-1.5-large-398b": "jamba_1_5_large_398b",
    "deepseek-moe-16b": "deepseek_moe_16b",
    "mixtral-8x22b": "mixtral_8x22b",
    "llava-next-34b": "llava_next_34b",
    "mamba2-370m": "mamba2_370m",
    "hubert-xlarge": "hubert_xlarge",
    "qwen3-32b": "qwen3_32b",
    "qwen3-4b": "qwen3_4b",
    "qwen2-0.5b": "qwen2_0_5b",
    "qwen3-8b": "qwen3_8b",
}

ARCH_IDS = tuple(_ARCH_MODULES)


def get_config(arch: str) -> ModelConfig:
    """Load the full (assigned) config for ``--arch <id>``."""
    if arch not in _ARCH_MODULES:
        raise KeyError(f"unknown arch {arch!r}; known: {sorted(_ARCH_MODULES)}")
    mod = importlib.import_module(f"repro.configs.{_ARCH_MODULES[arch]}")
    return mod.CONFIG


def get_smoke_config(arch: str) -> ModelConfig:
    """Reduced variant of the same family: ≤2 blocks, d_model ≤ 512,
    ≤4 experts."""
    if arch not in _ARCH_MODULES:
        raise KeyError(f"unknown arch {arch!r}; known: {sorted(_ARCH_MODULES)}")
    mod = importlib.import_module(f"repro.configs.{_ARCH_MODULES[arch]}")
    return mod.smoke_config()


def shape_is_applicable(cfg: ModelConfig, shape: InputShape) -> Tuple[bool, str]:
    """Whether (arch, shape) runs; returns (applicable, reason_if_not).

    See DESIGN.md §4 "Shape skips".
    """
    if shape.mode == "decode" and not cfg.is_decoder:
        return False, f"{cfg.name} is encoder-only: no decode step"
    if shape.name == "long_500k":
        subquadratic = (
            cfg.family in ("ssm", "hybrid")
            or cfg.sliding_window is not None)
        if not subquadratic:
            return False, (f"{cfg.name} is pure full-attention; long_500k "
                           "requires the sliding-window variant "
                           "(use long_context_variant())")
    return True, ""


def long_context_variant(cfg: ModelConfig, window: int = 8192) -> ModelConfig:
    """Sliding-window variant of a dense arch for long_500k (DESIGN.md §4)."""
    if cfg.family in ("ssm",):
        return cfg
    if cfg.sliding_window is not None:
        return cfg
    return dataclasses.replace(cfg, sliding_window=window,
                               name=cfg.name + "-swa")
