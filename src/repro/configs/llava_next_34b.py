"""LLaVA-NeXT-34B — VLM: dense decoder backbone; anyres vision frontend is a
stub per the carve-out (input_specs provides patch embeddings).
[hf:llava-hf/llava-v1.6-mistral-7b-hf]
"""
import dataclasses

from repro.configs.base import ModelConfig

# Number of precomputed vision-patch embedding positions assumed by
# input_specs for anyres tiling (base 576 + 4 tiles x 576).
NUM_PATCH_TOKENS = 2880

CONFIG = ModelConfig(
    name="llava-next-34b", family="vlm",
    num_layers=60, d_model=7168, num_heads=56, num_kv_heads=8,
    d_ff=20480, vocab_size=64000, head_dim=128,
    embedding_inputs=True,   # patch+token embeddings arrive precomputed
    source="hf:llava-hf/llava-v1.6-mistral-7b-hf (34B per assignment)",
)


def smoke_config() -> ModelConfig:
    return dataclasses.replace(
        CONFIG, name="llava-next-34b-smoke", num_layers=2, d_model=256,
        num_heads=4, num_kv_heads=2, head_dim=64, d_ff=512, vocab_size=512)
