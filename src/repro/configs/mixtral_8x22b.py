"""Mixtral-8x22B — MoE 8 experts top-2, GQA kv=8, SWA. [arXiv:2401.04088]"""
import dataclasses

from repro.configs.base import ModelConfig, MoEConfig

CONFIG = ModelConfig(
    name="mixtral-8x22b", family="moe",
    num_layers=56, d_model=6144, num_heads=48, num_kv_heads=8,
    d_ff=16384, vocab_size=32768, head_dim=128,
    sliding_window=4096,
    moe=MoEConfig(num_experts=8, num_experts_per_tok=2, d_expert=16384),
    source="arXiv:2401.04088",
)


def smoke_config() -> ModelConfig:
    return dataclasses.replace(
        CONFIG, name="mixtral-8x22b-smoke", num_layers=2, d_model=256,
        num_heads=4, num_kv_heads=2, head_dim=64, d_ff=512, vocab_size=512,
        sliding_window=64,
        moe=MoEConfig(num_experts=4, num_experts_per_tok=2, d_expert=512))
