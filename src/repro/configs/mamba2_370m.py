"""Mamba2-370M — attention-free SSD (state-space duality). [arXiv:2405.21060]"""
import dataclasses

from repro.configs.base import ModelConfig, SSMConfig

CONFIG = ModelConfig(
    name="mamba2-370m", family="ssm",
    num_layers=48, d_model=1024, num_heads=0, num_kv_heads=0,
    d_ff=0, vocab_size=50280, head_dim=64,
    layer_pattern=("mamba",),
    ssm=SSMConfig(d_state=128, d_conv=4, expand=2, head_dim=64),
    source="arXiv:2405.21060",
)


def smoke_config() -> ModelConfig:
    return dataclasses.replace(
        CONFIG, name="mamba2-370m-smoke", num_layers=2, d_model=256,
        vocab_size=512,
        ssm=SSMConfig(d_state=32, d_conv=4, expand=2, head_dim=64,
                      chunk_size=32))
