"""Qwen3-32B — dense decoder, GQA kv=8, qk_norm. [hf:Qwen/Qwen3-8B]"""
import dataclasses

from repro.configs.base import ModelConfig

CONFIG = ModelConfig(
    name="qwen3-32b", family="dense",
    num_layers=64, d_model=5120, num_heads=64, num_kv_heads=8,
    d_ff=25600, vocab_size=151936, head_dim=128,
    qk_norm=True, rope_theta=1e6,
    source="hf:Qwen/Qwen3-8B (scaled per assignment)",
)


def smoke_config() -> ModelConfig:
    return dataclasses.replace(
        CONFIG, name="qwen3-32b-smoke", num_layers=2, d_model=256,
        num_heads=4, num_kv_heads=2, head_dim=64, d_ff=512, vocab_size=512)
