"""Generic string-keyed class registry.

One mechanism backs every pluggable family in the repo (scheduler
policies, workload generators): classes register under a name via a
decorator, callers construct by name with one superset of keyword
arguments which is filtered against each class's ``__init__`` signature.
``repro.schedulers.registry`` and ``repro.workloads.registry`` are thin
domain wrappers around this class.
"""
from __future__ import annotations

import importlib
import inspect
from typing import Callable, Dict, List, Optional, Tuple, Type


class Registry:
    """Name -> (class, default kwargs) registry for one plugin family.

    ``kind`` names the family in error messages ("scheduler",
    "workload").  ``builtins_module`` is imported lazily on first use so
    the module holding the ``@register`` decorators can itself import
    the registry without a cycle.
    """

    def __init__(self, kind: str, builtins_module: Optional[str] = None):
        self.kind = kind
        self._builtins_module = builtins_module
        self._entries: Dict[str, Tuple[Type, dict]] = {}

    def _ensure_builtins(self) -> None:
        if self._builtins_module is not None:
            # Clear only after success: a failed import must re-raise its
            # real error on the next call, not leave the registry
            # silently empty.
            importlib.import_module(self._builtins_module)
            self._builtins_module = None

    def register(self, name: str, **defaults) -> Callable[[Type], Type]:
        """Class decorator registering ``cls`` under ``name``.

        ``defaults`` are keyword arguments merged (at lower priority)
        into every ``make(name, ...)`` call — useful for registering one
        class under several tunings.
        """
        def deco(cls: Type) -> Type:
            if name in self._entries:
                raise ValueError(
                    f"{self.kind} {name!r} already registered "
                    f"({self._entries[name][0].__qualname__})")
            self._entries[name] = (cls, dict(defaults))
            # Stamp the registered name unless the class itself (not a
            # base) already declares one.
            if not cls.__dict__.get("name"):
                cls.name = name
            return cls
        return deco

    def unregister(self, name: str) -> None:
        """Remove a registration (tests / plugin reload)."""
        self._entries.pop(name, None)

    def available(self) -> List[str]:
        """Sorted names of every registered class."""
        self._ensure_builtins()
        return sorted(self._entries)

    def cls(self, name: str) -> Type:
        self._ensure_builtins()
        try:
            return self._entries[name][0]
        except KeyError:
            raise ValueError(
                f"unknown {self.kind} {name!r}; available: "
                f"{self.available()}") from None

    def make(self, name: str, **kwargs):
        """Construct the class registered under ``name``.

        Keyword arguments the class's ``__init__`` does not accept are
        dropped (callers pass one superset for the whole family);
        missing *required* arguments still raise ``TypeError``.
        """
        self._ensure_builtins()
        if name not in self._entries:
            raise ValueError(
                f"unknown {self.kind} {name!r}; available: "
                f"{self.available()}")
        cls, defaults = self._entries[name]
        merged = {**defaults, **kwargs}
        if cls.__init__ is object.__init__:
            merged = {}
        else:
            sig = inspect.signature(cls.__init__)
            params = sig.parameters.values()
            if not any(p.kind is inspect.Parameter.VAR_KEYWORD
                       for p in params):
                accepted = {p.name for p in params}
                merged = {k: v for k, v in merged.items() if k in accepted}
        return cls(**merged)
