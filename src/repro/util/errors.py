"""Typed error hierarchy for the serving stack.

Retry logic (:mod:`repro.faults`) dispatches on the transient /
permanent split: anything deriving from :class:`TransientQueryError`
may be requeued against the per-query retry budget, everything else
propagates.  The hierarchy is deliberately small — fault kinds map
onto it one-to-one:

``flaky``   -> :class:`TransientQueryError`
``crash``   -> :class:`ReplicaUnavailableError` (replica down)
``hang``    -> :class:`DispatchTimeoutError` (stall > timeout)

:class:`MixedSequenceLengthError` (a batch-formation contract
violation, see docs/WORKLOADS.md) lives here too but is *permanent* —
retrying the same malformed batch can never succeed.
"""
from __future__ import annotations

from typing import Sequence

__all__ = [
    "QueryError",
    "TransientQueryError",
    "ReplicaUnavailableError",
    "DispatchTimeoutError",
    "MixedSequenceLengthError",
    "is_transient",
]


class QueryError(RuntimeError):
    """Base for all typed serving errors."""


class TransientQueryError(QueryError):
    """A query failed in a way that may succeed on retry.

    Raised by the ``flaky`` fault kind and subclassed by every other
    retryable failure.  Carries no replica state — the retry machinery
    decides where (and whether) to requeue.
    """


class ReplicaUnavailableError(TransientQueryError):
    """The routed replica is down (``crash`` fault window).

    Transient: the replica restarts at the end of its recovery delay,
    and other replicas may be healthy right now.
    """

    def __init__(self, replica: int = -1, until: float = float("nan")):
        self.replica = int(replica)
        self.until = float(until)
        super().__init__(f"replica {self.replica} unavailable "
                         f"until t={self.until:g}")


class DispatchTimeoutError(TransientQueryError):
    """A dispatch exceeded the per-dispatch timeout (``hang`` fault).

    The timed-out dispatch is charged as wasted occupancy on the
    replica that hung; the query itself becomes retryable.
    """

    def __init__(self, timeout: float = float("nan"),
                 replica: int = -1):
        self.timeout = float(timeout)
        self.replica = int(replica)
        super().__init__(f"dispatch exceeded timeout "
                         f"{self.timeout:g}s on replica {self.replica}")


class MixedSequenceLengthError(ValueError, QueryError):
    """A formed batch mixed padded sequence lengths (permanent).

    Kept a :class:`ValueError` subclass for backward compatibility
    with callers that caught the original
    ``repro.pipeline.executor.MixedSequenceLengthError``.
    """

    def __init__(self, lengths: Sequence[int]):
        self.lengths = [int(x) for x in lengths]
        super().__init__(
            "run_batch requires equal padded sequence lengths; got "
            f"{sorted(set(self.lengths))} — bucket queries by length "
            "(repro.workloads.buckets) before batching")


def is_transient(err: BaseException) -> bool:
    """True iff ``err`` may be retried under a retry budget."""
    return isinstance(err, TransientQueryError)
