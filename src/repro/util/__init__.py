"""Small shared infrastructure with no repro.* dependencies."""
from repro.util.registry import Registry  # noqa: F401
