"""Small shared infrastructure with no repro.* dependencies."""
from repro.util.errors import (  # noqa: F401
    DispatchTimeoutError,
    MixedSequenceLengthError,
    QueryError,
    ReplicaUnavailableError,
    TransientQueryError,
    is_transient,
)
from repro.util.registry import Registry  # noqa: F401
