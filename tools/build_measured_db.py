"""Build a *measured* layer-time interference database (paper §3.3).

Faithful to the paper's methodology on THIS container as the "real
platform": time every block of a real JAX model executing alone
(column 0), then re-time it while co-located stressor processes run —
iBench-style CPU busy-loops and memory-bandwidth streamers at the
Table-1 thread counts — giving the m x (n+1) table the simulator and
serving benchmarks consume.

    PYTHONPATH=src python tools/build_measured_db.py \
        [--arch qwen3-4b] [--blocks 12] [--out results/measured_db.json]
"""
from __future__ import annotations

import argparse
import ctypes
import dataclasses
import multiprocessing as mp
import time

import numpy as np


def _cpu_stressor(stop):
    x = 1.0001
    while not stop.value:
        for _ in range(10000):
            x = x * 1.0000001 + 1e-9
    return x


def _membw_stressor(stop):
    a = np.zeros(64 * 1024 * 1024 // 8)  # 64 MiB stream
    b = np.ones_like(a)
    while not stop.value:
        a += b                            # streaming read+write
    return a


@dataclasses.dataclass
class Scenario:
    name: str
    kind: str      # "cpu" | "membw"
    procs: int


def scenarios_table1():
    out = [Scenario("none", "none", 0)]
    # Table-1 thread counts, capped at 16 on this container (32 heavily
    # oversubscribes the sandbox cores and just measures the scheduler)
    for n in (1, 2, 4, 8, 16):
        out.append(Scenario(f"ibench-cpu-{n}t", "cpu", n))
    for n in (1, 2, 4, 8, 16):
        out.append(Scenario(f"ibench-membw-{n}t", "membw", n))
    return out


def measure(arch: str, blocks: int, seq: int, repeats: int):
    import dataclasses as dc

    import jax
    import jax.numpy as jnp

    from repro.configs import get_smoke_config
    from repro.models import Model
    from repro.pipeline import LocalPipelineExecutor

    cfg = get_smoke_config(arch)
    if blocks:
        cfg = dc.replace(cfg, num_layers=blocks * len(cfg.layer_pattern))
    model = Model(cfg)
    params = model.init_params(jax.random.PRNGKey(0), jnp.float32)
    ex = LocalPipelineExecutor(cfg, params)
    tokens = jnp.zeros((1, seq), jnp.int32)
    ex.warmup(1, seq)

    table = []
    names = []
    for sc in scenarios_table1():
        ctx = mp.get_context("spawn")   # fork deadlocks multithreaded JAX
        stop = ctx.Value(ctypes.c_int, 0)
        procs = []
        target = _cpu_stressor if sc.kind == "cpu" else _membw_stressor
        for _ in range(sc.procs):
            p = ctx.Process(target=target, args=(stop,), daemon=True)
            p.start()
            procs.append(p)
        try:
            time.sleep(0.3)  # let stressors ramp
            times = ex.measure_block_times(tokens, repeats=repeats)
        finally:
            stop.value = 1
            for p in procs:
                p.join(timeout=2)
                if p.is_alive():
                    p.terminate()
        table.append(times)
        names.append(sc.name)
        print(f"  {sc.name:18s} mean_block={1e3 * times.mean():7.2f} ms "
              f"(x{times.mean() / table[0].mean():.2f})", flush=True)
    return np.stack(table, axis=1), names, cfg


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="qwen3-4b")
    ap.add_argument("--blocks", type=int, default=12)
    ap.add_argument("--seq", type=int, default=128)
    ap.add_argument("--repeats", type=int, default=3)
    ap.add_argument("--out", default="results/measured_db.json")
    args = ap.parse_args()

    print(f"measuring {args.arch} ({args.blocks} blocks) under Table-1 "
          f"stressor scenarios...")
    table, names, cfg = measure(args.arch, args.blocks, args.seq,
                                args.repeats)
    from repro.core import LayerDatabase
    db = LayerDatabase(table, names,
                       unit_names=[f"block{i}" for i in range(len(table))],
                       model_name=f"{cfg.name}-measured")
    import os
    os.makedirs(os.path.dirname(args.out) or ".", exist_ok=True)
    db.save(args.out)
    print(f"saved {table.shape[0]}x{table.shape[1]} database -> {args.out}")
    print(f"impact range: x{(table[:, 1:] / table[:, :1]).min():.2f} .. "
          f"x{(table[:, 1:] / table[:, :1]).max():.2f}")


if __name__ == "__main__":
    main()
