"""End-to-end serving driver (the paper's kind of workload).

Serves batched requests through a REAL JAX transformer pipeline on this
host: 4 execution places, recompile-free dynamic stage boundaries,
physical interference injection, and the full ODIN monitor->detect->
rebalance loop on measured wall-clock stage times.  Compares ODIN, LLS
and a static pipeline over the same query stream + interference schedule,
then re-serves ODIN under an open-loop bursty (MMPP) arrival process to
show queueing delay reported separately from service latency
(docs/WORKLOADS.md).

Run:  PYTHONPATH=src python examples/serve_interference.py
"""
import dataclasses
import time

import jax
import jax.numpy as jnp
import numpy as np

from repro.configs import get_smoke_config
from repro.models import Model
from repro.serving import ServingEngine

ARCH = "qwen3-4b"
NUM_EPS = 4
NUM_QUERIES = 80
SEQ = 128

cfg = dataclasses.replace(get_smoke_config(ARCH), num_layers=8)
model = Model(cfg)
params = model.init_params(jax.random.PRNGKey(0), jnp.float32)
print(f"model: {cfg.name} ({cfg.num_blocks} blocks, "
      f"{cfg.param_count() / 1e6:.1f}M params), {NUM_EPS} execution places")

rng = np.random.default_rng(0)
queries = [jnp.asarray(rng.integers(0, cfg.vocab_size, (1, SEQ)))
           for _ in range(NUM_QUERIES)]


def schedule(q):
    """Two interference episodes: EP2 (queries 15-45), EP0 (50-70)."""
    slow = [1.0] * NUM_EPS
    if 15 <= q < 45:
        slow[2] = 3.0
    if 50 <= q < 70:
        slow[0] = 2.2
    return slow


results = {}
for sched in ("odin", "lls", "hybrid", "none"):
    eng = ServingEngine(cfg, params, num_eps=NUM_EPS, scheduler=sched,
                        alpha=4)
    eng.executor.warmup(1, SEQ)
    t0 = time.perf_counter()
    m = eng.serve(queries, schedule)
    wall = time.perf_counter() - t0
    s = m.summary()
    results[sched] = s
    print(f"\n{sched.upper():5s}  wall={wall:.1f}s")
    print(f"  mean latency  : {s['mean_latency_s'] * 1e3:7.2f} ms")
    print(f"  p99 latency   : {s['p99_latency_s'] * 1e3:7.2f} ms")
    print(f"  throughput    : {s['mean_throughput_qps']:7.1f} q/s "
          "(pipeline capability)")
    print(f"  rebalances    : {s['rebalances']}  "
          f"(serial fraction {100 * s['serial_frac']:.0f}%)")
    print(f"  final config  : {m.configs[-1]}")

odin, lls = results["odin"], results["lls"]
odin_vs_lls = 100 * (1 - odin['mean_latency_s'] / lls['mean_latency_s'])
print(f"\nODIN vs LLS: {odin_vs_lls:+.1f}% "
      f"mean latency, "
      f"{100 * (odin['mean_throughput_qps'] / lls['mean_throughput_qps'] - 1):+.1f}% "
      f"throughput")

# --- open-loop bursty traffic + batched serving ----------------------------
# The runs above are closed-loop: a saturated back-to-back stream, the
# paper's methodology.  Real serving traffic is open-loop and bursty —
# queries arrive on their own clock and queue when a burst outruns the
# pipeline.  Same engine, same scheduler; only the workload changes, and
# the trace separates queueing delay from service latency.
#
# serve(max_batch=N) then lets a burst amortize: queries that queued up
# are stacked and run through every stage once (one set of stage
# dispatches + syncs per batch).  Freezing the engine's block-time
# estimates (estimate_beta = 0 after a short calibration window) makes
# the scheduling layer deterministic, so the batched and unbatched runs
# take the identical detect -> explore -> commit walk and differ ONLY in
# execution granularity — an apples-to-apples A/B of batching.
eng = ServingEngine(cfg, params, num_eps=NUM_EPS, scheduler="odin", alpha=4,
                    estimate_beta=0.3)
eng.executor.warmup(1, SEQ)
probe = eng.serve(queries[:10], lambda q: [1.0] * NUM_EPS)  # calibrate
mean_service = float(probe.service_latencies[3:].mean())
eng.estimate_beta = 0.0          # freeze -> reproducible scheduling
bursty_kwargs = dict(
    burst_rate=6.0 / mean_service,       # bursts outrun the pipeline
    base_rate=0.3 / mean_service,        # quiet phases drain the queue
    mean_burst=40 * mean_service, mean_gap=20 * mean_service, seed=0)

batched = {}
for max_batch in (1, 8):
    eng.reset_policy()               # fresh window, same frozen estimates
    m = eng.serve(queries, schedule, workload="bursty",
                  workload_kwargs=bursty_kwargs, max_batch=max_batch)
    batched[max_batch] = m
    s = m.summary()
    print(f"\nODIN under bursty arrivals (MMPP on/off), "
          f"max_batch={max_batch}:")
    print(f"  offered load  : {s['offered_load_qps']:7.1f} q/s  "
          f"(achieved {s['achieved_load_qps']:.1f} q/s)")
    print(f"  mean latency  : {s['mean_latency_s'] * 1e3:7.2f} ms  "
          f"= queue {s['mean_queue_delay_s'] * 1e3:.2f} ms "
          f"+ service {s['mean_service_latency_s'] * 1e3:.2f} ms")
    print(f"  p99 queue wait: {s['p99_queue_delay_s'] * 1e3:7.2f} ms   "
          f"max in-system depth: {int(m.queue_depths.max())}")
    print(f"  rebalances    : {s['rebalances']}  "
          f"(trials {m.total_trials}, serial fraction "
          f"{100 * s['serial_frac']:.0f}%)")

m1, m8 = batched[1], batched[8]
acct_match = (m8.num_rebalances == m1.num_rebalances
              and m8.total_trials == m1.total_trials
              and m8.configs_trace == m1.configs_trace)
print("\nBatching (max_batch=8 vs 1) at the same offered load:")
print(f"  mean queue delay: {m1.mean_queue_delay * 1e3:.2f} -> "
      f"{m8.mean_queue_delay * 1e3:.2f} ms "
      f"({m1.mean_queue_delay / max(m8.mean_queue_delay, 1e-12):.1f}x lower)")
print(f"  achieved load   : {m1.achieved_load:.1f} -> "
      f"{m8.achieved_load:.1f} q/s")
print(f"  rebalance/trial accounting identical: {acct_match} "
      f"(rebalances {m8.num_rebalances}, trials {m8.total_trials})")

# --- continuous batching + length buckets ----------------------------------
# Drain-mode batching above only helps queries that are ALREADY queued
# when a dispatch forms; anything arriving a moment later waits out the
# whole group-synchronous drain.  batching="continuous" admits those
# arrivals into the in-flight batch at pipeline-stage boundaries — one
# fused catch-up launch (embed + the stages the batch already ran) and
# the batch resumes one row wider.  Length-bucketed dispatch keeps the
# mixed short/long stream from padding every batch to the longest
# member: dispatches group by power-of-two bucket, and every compiled
# shape comes from the small pre-warmed {rows} x {bucket edges} set
# (docs/WORKLOADS.md "Continuous batching & length buckets").
#
# Regime matters: joins pay off when drain mode would have QUEUED the
# arrival (loaded pipeline); at near-idle a solo dispatch is already
# optimal and group-synchronous completion makes joins pure delay
# (docs/PERFORMANCE.md).
mixed = [jnp.asarray(rng.integers(0, cfg.vocab_size,
                                  (1, 128 if rng.random() < 0.15 else 48)))
         for _ in range(NUM_QUERIES)]
# Re-calibrate on the mixed stream: short queries serve ~2x faster than
# the all-128 probe above, and anchoring the arrival rate on the wrong
# service time would land the A/B in the near-idle regime.
probe = eng.serve(mixed[:10], lambda q: [1.0] * NUM_EPS)
mixed_service = float(probe.service_latencies[3:].mean())
cont_kwargs = dict(rate=0.35 / mixed_service,
                   burst_rate=1.5 / mixed_service, burst_prob=0.08, seed=2)
cont = {}
for mode in ("drain", "continuous"):
    eng.reset_policy()
    m = eng.serve(mixed, schedule, workload="bursty",
                  workload_kwargs=cont_kwargs,
                  batching=mode, max_batch=8, buckets="pow2:64:128")
    cont[mode] = s = m.summary()
    print(f"\nODIN, mixed lengths (48/128), batching={mode}:")
    print(f"  mean queue delay: {s['mean_queue_delay_s'] * 1e3:7.2f} ms   "
          f"p99 {s['p99_queue_delay_s'] * 1e3:.2f} ms")
    print(f"  batch occupancy : {s['mean_batch_occupancy']:7.2f}    "
          f"padded-token waste {100 * s['padded_token_frac']:.0f}%")

ratio = (cont["drain"]["mean_queue_delay_s"]
         / max(cont["continuous"]["mean_queue_delay_s"], 1e-12))
print(f"\nContinuous vs drain at the same offered load: "
      f"{ratio:.2f}x lower mean queue delay")
print("(live wall-clock A/B on a shared host is noisy run to run; the "
      "deterministic, CI-gated comparison is benchmarks/runner_bench.py's "
      "bursty_batching row)")
