"""Live metrics dashboard: watch a bursty run through a MetricsSink.

``trace_mode="streaming"`` (docs/TELEMETRY.md) folds per-query
telemetry into constant-memory sketches as the run advances, and a
:class:`~repro.telemetry.MetricsSink` receives a registry snapshot
every ``sink_interval`` arrivals — the same numbers a Prometheus
scrape would see.  This demo drives a bursty overload through SLO
shedding (docs/CONTROL.md) and renders each snapshot as one dashboard
row, so you can watch the queue build during bursts, the shedder
engage, and p99 hold near the SLO while attainment stays high.

Run:  PYTHONPATH=src python examples/metrics_dashboard.py
"""
from repro.core import simulate, synthetic_database
from repro.telemetry import CallbackSink

NUM_QUERIES = 40_000

db = synthetic_database("vgg16", seed=0)
probe = simulate(db, 4, scheduler="none", events=[], num_queries=10)
cap = probe.peak_throughput
slo = 3.0 * float(probe.service_latencies[-1])
print(f"model: vgg16 database, 4 EPs, peak {cap:.4f} q/unit, "
      f"SLO {slo:.0f} units")

HEADER = (f"{'arrivals':>9s} {'admitted':>9s} {'shed':>7s} "
          f"{'offered q/s':>12s} {'goodput q/s':>12s} "
          f"{'p99 lat':>9s} {'attain':>7s} {'depth':>6s}")
print(HEADER)
print("-" * len(HEADER))


def render(snap):
    """One dashboard row per registry snapshot."""
    lat = snap["repro_latency_seconds"]
    print(f"{snap['repro_queries_offered_total']:9.0f} "
          f"{snap['repro_queries_admitted_total']:9.0f} "
          f"{snap['repro_queries_shed_total']:7.0f} "
          f"{snap['repro_offered_qps']:12.5f} "
          f"{snap['repro_goodput_qps']:12.5f} "
          f"{lat['quantiles']['0.99']:9.1f} "
          f"{snap['repro_slo_attainment']:7.3f} "
          f"{snap['repro_queue_depth']:6.0f}")


trace = simulate(
    db, 4, scheduler="none", events=[], num_queries=NUM_QUERIES,
    workload="bursty",
    workload_kwargs=dict(burst_rate=3.0 * cap, base_rate=0.5 * cap,
                         mean_burst=2000.0 / cap, mean_gap=1000.0 / cap,
                         seed=7),
    admission="slo_shed", admission_kwargs=dict(slo=slo),
    trace_mode="streaming", metrics_sink=CallbackSink(render),
    sink_interval=4000)

print("-" * len(HEADER))
s = trace.summary()
print(f"final: {trace.num_admitted} admitted / {trace.num_shed} shed "
      f"({s['shed_rate']:.1%}), p99 {s['p99_latency_s']:.1f} "
      f"(SLO {slo:.0f}), attainment {s['slo_attainment']:.3f}")

# The same registry, as Prometheus text exposition (what an exporter
# endpoint would serve) -- first few lines:
for line in trace.prometheus().splitlines()[:6]:
    print("  " + line)
