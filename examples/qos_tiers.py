"""QoS tiers demo: value-aware serving on a heterogeneous fleet.

Two traffic classes share a 4-replica fleet under bursty overload: a
"gold" tier (15% of arrivals, priority 2, value 10, tight deadline)
and a "batch" tier (85%, priority 0, value 1, loose deadline).  Two of
the four replicas run a half-cost small model (`pools=["small", ...]`,
docs/QOS.md).  Compares three control configurations:

* qos          -- downgrade routing + expected-value shedding: gold
                  traffic keeps the full models, pressured batch
                  traffic degrades to the small pool instead of
                  shedding;
* slo_shed     -- same router, tier-blind latency shedding: sheds
                  batch queries whose own (loose) deadline was
                  perfectly attainable;
* round_robin  -- fleet- and tier-blind baseline: gold queries queue
                  behind batch bursts and blow their deadlines.

Run:  PYTHONPATH=src python examples/qos_tiers.py
"""
from repro.cluster import simulate_cluster
from repro.core import synthetic_database

NUM_QUERIES = 600

# The fleet: two full-model replicas, two at half the per-layer cost
# (a distilled / quantized build of the same architecture).
full = synthetic_database("vgg16", base_time=10.0, seed=0)
small = synthetic_database("vgg16", base_time=5.0, seed=0)

TIERS = [dict(name="gold", priority=2, value=10.0, deadline=800.0),
         dict(name="batch", priority=0, value=1.0, deadline=6000.0)]

CONFIGS = {
    "qos": dict(router="downgrade",
                router_kwargs=dict(pressure=0.0, priority_max=0),
                admission="value_shed",
                admission_kwargs=dict(theta=0.5)),
    "slo_shed": dict(router="downgrade",
                     router_kwargs=dict(pressure=0.0, priority_max=0),
                     admission="slo_shed",
                     admission_kwargs=dict(slo=800.0)),
    "round_robin": dict(router="round_robin"),
}

results = {}
for name, kw in CONFIGS.items():
    ct = simulate_cluster(
        full, 4, num_replicas=4,
        databases=[full, full, small, small],
        pools=["default", "default", "small", "small"],
        scheduler="none", num_queries=NUM_QUERIES,
        tiers=TIERS, tiers_kwargs=dict(shares=[0.15, 0.85], seed=5),
        workload="bursty",
        workload_kwargs=dict(burst_rate=0.16, base_rate=0.004,
                             mean_burst=400.0, mean_gap=400.0, seed=7),
        **kw)
    s = ct.summary()
    results[name] = s
    print(f"\n{name.upper()}")
    for tier in ("gold", "batch"):
        print(f"  {tier:5s}: served {s[f'tier_{tier}_num']:4.0f}  "
              f"shed {s[f'tier_{tier}_shed']:3.0f}  "
              f"downgraded {s.get(f'tier_{tier}_downgraded', 0):3.0f}  "
              f"p99 {s[f'tier_{tier}_p99_latency_s']:7.1f}  "
              f"attainment {s[f'tier_{tier}_deadline_attainment']:.3f}")
    print(f"  realized value  : {s['realized_value']:.0f} "
          f"of {s['offered_value']:.0f} offered")
    print(f"  fleet shed rate : {100 * s['shed_rate']:.1f}%")

qos, blind, rr = (results[k] for k in ("qos", "slo_shed", "round_robin"))
print(f"\nvalue-aware vs tier-blind shedding: "
      f"{qos['realized_value']:.0f} vs {blind['realized_value']:.0f} "
      f"realized value ({blind['num_shed']:.0f} queries shed needlessly)")
print(f"value-aware vs fleet-blind routing: gold attainment "
      f"{qos['tier_gold_deadline_attainment']:.3f} vs "
      f"{rr['tier_gold_deadline_attainment']:.3f}")
