"""Train a ~100M-parameter model for a few hundred steps on synthetic data.

Exercises the full training substrate: model zoo config, synthetic data
pipeline (with an induction-copy pattern the model can learn), hand-rolled
AdamW with warmup+cosine schedule, remat, and checkpointing.

Run:  PYTHONPATH=src python examples/train_small.py [--steps 300]
"""
import argparse
import dataclasses

import jax.numpy as jnp

from repro.configs import get_smoke_config
from repro.training import AdamWConfig, train
from repro.training.data import SyntheticLM

ap = argparse.ArgumentParser()
ap.add_argument("--steps", type=int, default=300,
                help="~100M params: ~8 s/step on CPU; --steps 30 for a smoke run")
ap.add_argument("--checkpoint-dir", default="/tmp/repro_train_small")
args = ap.parse_args()

# ~100M params: qwen-style dense, 8 layers, d_model 768.
cfg = dataclasses.replace(
    get_smoke_config("qwen3-8b"),
    name="qwen3-100m", num_layers=8, d_model=768, num_heads=12,
    num_kv_heads=4, head_dim=64, d_ff=2048, vocab_size=50257)
print(f"{cfg.name}: {cfg.param_count() / 1e6:.1f}M params")

data = SyntheticLM(cfg.vocab_size, seq_len=128, global_batch=4, seed=0)
opt = AdamWConfig(lr=6e-4, warmup_steps=30, total_steps=args.steps)

out = train(cfg, opt, iter(data), args.steps, dtype=jnp.float32,
            log_every=20, checkpoint_dir=args.checkpoint_dir,
            checkpoint_every=100)

first, last = out["history"][0], out["history"][-1]
print(f"\nloss {first['loss']:.3f} -> {last['loss']:.3f} over {args.steps} "
      f"steps ({last['wall_s']:.0f}s)")
assert last["loss"] < first["loss"], "model failed to learn"
print(f"checkpoints in {args.checkpoint_dir}")
