"""Fault-tolerant fleet demo: crash, recover, hedge (docs/FAULTS.md).

Part 1 — crash and recover: replica 1 of a 3-replica fleet goes down
mid-run (a wall-clock crash window, so it *restarts*), and later the
whole fleet hits a flaky patch.  Without a retry budget every failed
attempt is a lost query; with retries plus a circuit breaker the fleet
re-routes around the outage, rides out the flakiness, probes the
replica at its recovery time, and hands traffic back — availability
bought with some tail latency on the retried queries.

Part 2 — tail-latency hedging: one replica is permanently 5x slow.
Dispatches that would sit behind its backlog longer than
``hedge_after`` are speculatively re-issued on a healthy peer; the
first projected finisher wins, and a loser that had actually started
is charged as wasted work.

Run:  PYTHONPATH=src python examples/cluster_faults.py
"""
import numpy as np

from repro.cluster import simulate_cluster
from repro.core import simulate, synthetic_database
from repro.faults import FaultEvent, FaultPlan

NUM_REPLICAS = 3
NUM_EPS = 3
NUM_QUERIES = 1500

db = synthetic_database("vgg16", seed=0)
cap = simulate(db, NUM_EPS, scheduler="none", events=[],
               num_queries=10).peak_throughput
rate = 0.55 * NUM_REPLICAS * cap
horizon = NUM_QUERIES / rate
wl = dict(rate=rate, seed=7)
print(f"vgg16 database, {NUM_REPLICAS} replicas x {NUM_EPS} EPs, "
      f"poisson arrivals at {rate:.4f} q/unit (~{horizon:.0f} units)")

# -- Part 1: crash + recover -------------------------------------------------
outage = FaultPlan(events=[
    FaultEvent("crash", start=0.25 * horizon, duration=0.25 * horizon,
               replica=1),
    FaultEvent("flaky", start=0.6 * horizon, duration=0.2 * horizon,
               p=0.4),
], seed=0, time_indexed=True)
print(f"\nPart 1: replica 1 down for t=[{outage.events[0].start:.0f}, "
      f"{outage.events[0].end:.0f}), fleet-wide 40% flakiness for "
      f"t=[{outage.events[1].start:.0f}, {outage.events[1].end:.0f})")

common = dict(scheduler="odin", num_queries=NUM_QUERIES,
              workload="poisson", workload_kwargs=wl,
              router="least_outstanding", faults=outage)
runs = {
    "no retries": simulate_cluster(db, NUM_EPS, NUM_REPLICAS,
                                   retries=0, **common),
    "retries + breaker": simulate_cluster(
        db, NUM_EPS, NUM_REPLICAS,
        retries=dict(max_retries=4, backoff=0.002 * horizon, jitter=0.5),
        health_kwargs=dict(failure_threshold=4,
                           cooldown=0.02 * horizon),
        **common),
}
for name, ct in runs.items():
    s = ct.summary()
    post = int(np.sum(ct.replicas[1].arrival_times
                      > outage.events[0].end))
    print(f"  {name:18s} availability {s['availability']:.4f}  "
          f"failed {s['num_failed']:3.0f}  retried {s['num_retried']:3.0f}  "
          f"p99 {s['p99_latency_s']:7.0f}  "
          f"replica-1 queries after recovery: {post}")

# -- Part 2: hedging the slow replica ----------------------------------------
laggard = FaultPlan(events=[
    FaultEvent("slowdown", start=0.0, duration=1e12, replica=0,
               factor=5.0),
], seed=0)
print("\nPart 2: replica 0 permanently 5x slow, round-robin routing")
wl2 = dict(rate=0.4 * NUM_REPLICAS * cap, seed=7)
common = dict(scheduler="none", num_queries=NUM_QUERIES,
              workload="poisson", workload_kwargs=wl2,
              router="round_robin", faults=laggard, retries=1)
straight = simulate_cluster(db, NUM_EPS, NUM_REPLICAS, **common).summary()
hedged = simulate_cluster(db, NUM_EPS, NUM_REPLICAS,
                          hedge_after=4.0 / cap, **common).summary()
for name, s in (("no hedging", straight), ("hedge_after", hedged)):
    print(f"  {name:12s} p50 {s['p50_latency_s']:8.1f}  "
          f"p99 {s['p99_latency_s']:8.1f}  "
          f"hedged {s['num_hedged']:4.0f}  "
          f"wasted work {100 * s['wasted_work_frac']:5.1f}%")
print(f"\nhedging: {straight['p99_latency_s'] / hedged['p99_latency_s']:.1f}x "
      "lower fleet p99 (the hedge steals the query before the slow "
      "replica ever starts it, so little work is actually wasted)")
