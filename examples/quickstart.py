"""Quickstart: ODIN in 60 seconds.

1. Build the paper's interference database (VGG16 profile, 12 scenarios).
2. Break a balanced 4-stage pipeline with a co-located workload.
3. Watch ODIN rebalance it online, and compare with LLS + the DP oracle.

Run:  PYTHONPATH=src python examples/quickstart.py
"""
import numpy as np

from repro.core import (
    SimTimeSource,
    lls_rebalance,
    odin_rebalance,
    optimal_partition,
    synthetic_database,
    throughput,
)

db = synthetic_database("vgg16")
print(f"database: {db.num_layers} layers x (1 + {db.num_scenarios} "
      f"interference scenarios)\n")

# Balanced starting configuration on 4 execution places, no interference.
config, peak = optimal_partition(db, [0, 0, 0, 0], 4)
print(f"clean optimum: {config} -> throughput {peak:.5f} q/unit-time")

# A memBW stressor lands on the bottleneck EP.
clean = SimTimeSource(db, [0, 0, 0, 0])
ep = int(np.argmax(clean.stage_times(config)))
scenarios = [0] * 4
scenarios[ep] = 10
src = SimTimeSource(db, scenarios)
hit = throughput(src.stage_times(config))
print(f"interference on EP{ep}: throughput drops {peak:.5f} -> {hit:.5f} "
      f"({100 * (1 - hit / peak):.0f}% loss)\n")

# ODIN (Algorithm 1) reacts using only observed stage times.
for alpha in (2, 10):
    res = odin_rebalance(config, alpha, src)
    print(f"ODIN alpha={alpha:2d}: {res.config} -> {res.throughput:.5f} "
          f"({res.num_trials} serially-processed trial queries)")

lls = lls_rebalance(config, src)
print(f"LLS          : {lls.config} -> {lls.throughput:.5f} "
      f"({lls.num_trials} trials)")

oracle_cfg, oracle_T = optimal_partition(db, scenarios, 4)
print(f"DP oracle    : {oracle_cfg} -> {oracle_T:.5f} "
      f"(the paper's 42.5-minute exhaustive search, in milliseconds)")

rec = odin_rebalance(config, 10, src).throughput
print(f"\nODIN recovered {100 * rec / oracle_T:.0f}% of the "
      f"resource-constrained optimum.")

# Every mitigation policy is a pluggable scheduler (docs/SCHEDULERS.md):
# build one by name and drive it with the shared rebalance runtime —
# the same state machine the simulator and the live engine use.
from repro.schedulers import RebalanceRuntime, available_schedulers, \
    make_scheduler  # noqa: E402

print(f"\nregistered schedulers: {', '.join(available_schedulers())}")
rt = RebalanceRuntime(make_scheduler("hybrid", alpha=10), config)
rt.poll(clean)         # one quiet query records the clean baseline
trials = 0
while True:
    step = rt.poll(src)
    if not step.serial:
        break
    trials += 1
print(f"hybrid policy: {rt.config} -> "
      f"{throughput(src.stage_times(rt.config)):.5f} ({trials} trials)")
