"""Fleet serving demo: interference-aware routing across 4 replicas.

One pipeline replica getting hammered by co-located stressors (the
paper's heaviest setting, freq=2 dur=100, scoped to replica 2) while a
diurnal day/night load swing drives the fleet.  Compares the three
built-in routers on fleet p99 / throughput / SLO violations and shows
*why* odin_aware wins: it watches each replica's ODIN detector and
routes around the victim the moment interference is detected, instead
of waiting for a backlog (least_outstanding) or ignoring it entirely
(round_robin).

Run:  PYTHONPATH=src python examples/cluster_routing.py
"""
import dataclasses

from repro.cluster import available_routers, simulate_cluster
from repro.core import generate_events, simulate, synthetic_database

NUM_REPLICAS = 4
NUM_QUERIES = 4000
VICTIM = 2

db = synthetic_database("vgg16", seed=0)
cap = simulate(db, NUM_REPLICAS, scheduler="none", events=[],
               num_queries=10).peak_throughput
print(f"model: vgg16 database, {NUM_REPLICAS} replicas x "
      f"{NUM_REPLICAS} EPs, per-replica peak {cap:.4f} q/unit")

# The paper's freq=2, dur=100 event storm -- but only on replica 2.
events = [dataclasses.replace(ev, replica=VICTIM)
          for ev in generate_events(NUM_QUERIES // NUM_REPLICAS,
                                    NUM_REPLICAS, db.num_scenarios,
                                    2, 100, seed=5)]

# Diurnal fleet traffic: mean load ~60% of clean fleet capacity,
# swinging +-80% over the "day".
workload_kwargs = dict(mean_rate=0.6 * NUM_REPLICAS * cap,
                       period=NUM_QUERIES / (2.0 * cap),
                       amplitude=0.8, seed=7)

results = {}
for router in available_routers():
    ct = simulate_cluster(db, NUM_REPLICAS, NUM_REPLICAS,
                          scheduler="odin", alpha=10,
                          num_queries=NUM_QUERIES, events=events,
                          router=router, workload="diurnal",
                          workload_kwargs=workload_kwargs)
    s = ct.summary()
    results[router] = s
    shares = [f"{c / NUM_QUERIES:.0%}" for c in ct.replica_counts]
    print(f"\n{router.upper()}")
    print(f"  fleet p50 / p99 : {s['p50_latency_s']:9.1f} / "
          f"{s['p99_latency_s']:9.1f}")
    print(f"  mean queue delay: {s['mean_queue_delay_s']:9.1f}")
    print(f"  achieved load   : {s['achieved_load_qps']:.4f} q/unit "
          f"(offered {s['offered_load_qps']:.4f})")
    print(f"  SLO violations  : {100 * s['slo_violations']:.1f}%  "
          f"(throughput < 90% of own replica's peak)")
    print(f"  replica shares  : {shares}   <- victim is replica {VICTIM}")
    print(f"  rebalances      : {s['rebalances']} across the fleet")

rr, oa = results["round_robin"], results["odin_aware"]
print(f"\nodin_aware vs round_robin: "
      f"{rr['p99_latency_s'] / oa['p99_latency_s']:.1f}x lower fleet p99, "
      f"{100 * (oa['achieved_load_qps'] / rr['achieved_load_qps'] - 1):+.0f}% "
      f"achieved load, "
      f"SLO violations {100 * rr['slo_violations']:.1f}% -> "
      f"{100 * oa['slo_violations']:.1f}%")
