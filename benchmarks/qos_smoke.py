"""CI QoS smoke: 2 tiers, bursty overload, heterogeneous 4-replica fleet.

Runs the QoS acceptance scenario (docs/QOS.md): a gold tier (priority
2, value 10, 800 time-unit deadline) and a batch tier (priority 0,
value 1, loose deadline) over a fleet of two full-model and two
small-model replicas under bursty (MMPP) overload.  Three control
configurations are compared:

* ``qos`` — downgrade routing + expected-value shedding,
* ``slo_shed`` — the same router with tier-blind latency shedding,
* ``round_robin`` — a fleet-blind router, no admission control.

Writes the per-configuration per-tier metrics to
``results/benchmarks/qos_smoke.csv`` and fails unless the tier-aware
control plane pays off:

* ``qos`` gold-tier deadline attainment >= 0.99 while the fleet-blind
  baseline violates it,
* ``qos`` realized value strictly above *both* baselines,
* dense vs streaming per-tier p99 within 1%, and the run is
  deterministic (identical summary on a rerun).

    REPRO_QOS_QUERIES=600 PYTHONPATH=src python -m benchmarks.qos_smoke
"""
from __future__ import annotations

import csv
import math
import os
import sys

from benchmarks.common import RESULTS_DIR
from repro.cluster import simulate_cluster
from repro.core.database import synthetic_database

NUM_QUERIES = int(os.environ.get("REPRO_QOS_QUERIES", "600"))

TIERS = [dict(name="gold", priority=2, value=10.0, deadline=800.0),
         dict(name="batch", priority=0, value=1.0, deadline=6000.0)]

TIER_COLS = ("num", "shed", "p50_latency_s", "p99_latency_s",
             "deadline_attainment", "downgraded")


def run(full, small, name, router, admission, rk=None, ak=None,
        trace_mode="dense"):
    ct = simulate_cluster(
        full, 4, num_replicas=4,
        databases=[full, full, small, small],
        pools=["default", "default", "small", "small"],
        scheduler="none",
        router=router, router_kwargs=rk,
        admission=admission, admission_kwargs=ak,
        num_queries=NUM_QUERIES,
        tiers=TIERS, tiers_kwargs=dict(shares=[0.15, 0.85], seed=5),
        workload="bursty",
        workload_kwargs=dict(burst_rate=0.16, base_rate=0.004,
                             mean_burst=400.0, mean_gap=400.0, seed=7),
        trace_mode=trace_mode)
    s = ct.summary()
    row = {"config": name, "trace_mode": trace_mode,
           "num_queries": NUM_QUERIES, "router": router,
           "admission": admission or "none",
           "offered_value": s["offered_value"],
           "realized_value": s["realized_value"],
           "num_shed": s["num_shed"]}
    for tier in ("gold", "batch"):
        for col in TIER_COLS:
            key = f"tier_{tier}_{col}"
            row[key] = s.get(key, 0.0)
    return row


def main() -> int:
    full = synthetic_database("vgg16", base_time=10.0, seed=0)
    small = synthetic_database("vgg16", base_time=5.0, seed=0)

    configs = [
        ("qos", "downgrade", "value_shed",
         dict(pressure=0.0, priority_max=0), dict(theta=0.5)),
        ("slo_shed", "downgrade", "slo_shed",
         dict(pressure=0.0, priority_max=0), dict(slo=800.0)),
        ("round_robin", "round_robin", None, None, None),
    ]
    rows, by_name = [], {}
    for name, router, admission, rk, ak in configs:
        row = run(full, small, name, router, admission, rk=rk, ak=ak)
        rows.append(row)
        by_name[name] = row
        print(f"{name:12s} realized value {row['realized_value']:8.1f}  "
              f"gold attainment {row['tier_gold_deadline_attainment']:.4f}  "
              f"shed {row['num_shed']:.0f}  "
              f"downgraded {row['tier_batch_downgraded']:.0f}")
    stream = run(full, small, "qos", "downgrade", "value_shed",
                 rk=dict(pressure=0.0, priority_max=0),
                 ak=dict(theta=0.5), trace_mode="streaming")
    rows.append(stream)
    rerun = run(full, small, "qos", "downgrade", "value_shed",
                rk=dict(pressure=0.0, priority_max=0), ak=dict(theta=0.5))

    os.makedirs(RESULTS_DIR, exist_ok=True)
    path = os.path.join(RESULTS_DIR, "qos_smoke.csv")
    with open(path, "w", newline="") as f:
        w = csv.DictWriter(f, fieldnames=list(rows[0].keys()))
        w.writeheader()
        w.writerows(rows)

    qos = by_name["qos"]
    failed = []
    bad = [(r["config"], k) for r in rows for k, v in r.items()
           if isinstance(v, float) and not math.isfinite(v)]
    if bad:
        failed.append(f"non-finite columns: {bad}")
    if qos["tier_gold_deadline_attainment"] < 0.99:
        failed.append(f"qos gold attainment "
                      f"{qos['tier_gold_deadline_attainment']:.4f} < 0.99")
    if by_name["round_robin"]["tier_gold_deadline_attainment"] >= 0.99:
        failed.append("fleet-blind round_robin unexpectedly met the "
                      "gold objective — the scenario is not an overload")
    for base in ("slo_shed", "round_robin"):
        if qos["realized_value"] <= by_name[base]["realized_value"]:
            failed.append(
                f"qos realized value {qos['realized_value']:.1f} <= "
                f"{base} {by_name[base]['realized_value']:.1f}")
    for tier in ("gold", "batch"):
        k = f"tier_{tier}_p99_latency_s"
        if abs(stream[k] - qos[k]) > 0.01 * qos[k]:
            failed.append(f"dense/streaming {k} diverge: "
                          f"{qos[k]:.2f} vs {stream[k]:.2f}")
    drift = [k for k, v in qos.items() if rerun[k] != v]
    if drift:
        failed.append(f"non-deterministic columns: {drift}")

    if failed:
        print("qos_smoke FAILED: " + "; ".join(failed))
        return 1
    print(f"qos_smoke OK -> {path}")
    return 0


if __name__ == "__main__":
    sys.exit(main())
