"""Roofline report: aggregate the dry-run JSONs into the (arch x shape x
mesh) table consumed by EXPERIMENTS.md §Roofline."""
from __future__ import annotations

import glob
import json
import os

from benchmarks.common import write_csv

DRYRUN_DIR = os.environ.get("REPRO_DRYRUN", "results/dryrun")


def run() -> list:
    rows = []
    for path in sorted(glob.glob(os.path.join(DRYRUN_DIR, "*.json"))):
        with open(path) as f:
            d = json.load(f)
        if d.get("error"):
            rows.append({"arch": d["arch"], "shape": d["shape"],
                         "mesh": "?", "status": "ERROR"})
            continue
        if d.get("skipped"):
            rows.append({"arch": d["arch"], "shape": d["shape"],
                         "mesh": "-", "status": f"SKIP: {d['reason']}"})
            continue
        r = d["roofline"]
        m = d["memory"]
        rows.append({
            "arch": d["arch"], "shape": d["shape"], "mesh": d["mesh"],
            "status": "ok", "mode": d["mode"],
            "t_compute_s": f"{r['t_compute_s']:.4g}",
            "t_memory_s": f"{r['t_memory_s']:.4g}",
            "t_collective_s": f"{r['t_collective_s']:.4g}",
            "bottleneck": r["bottleneck"],
            "flops": f"{r['flops']:.4g}",
            "bytes": f"{r['bytes_accessed']:.4g}",
            "coll_bytes": f"{r['collective_bytes']:.4g}",
            "model_flops": f"{r['model_flops']:.4g}",
            "useful_ratio": f"{(r['useful_ratio'] or 0):.3f}",
            "args_gib_per_dev": f"{m['argument_bytes'] / 2**30:.3f}",
            "compile_s": d["compile_s"],
        })
    write_csv("roofline", rows)
    return rows
