"""Kernel micro-benchmarks: Pallas (interpret) correctness-checked paths
timed via their XLA reference implementations on CPU (wall time of the
ref path; the Pallas path is TPU-targeted and validated in tests)."""
from __future__ import annotations

import time

import jax
import jax.numpy as jnp

from repro.kernels import ref as R
from benchmarks.common import write_csv


def _time(f, *args, n=5):
    f(*args).block_until_ready()
    t0 = time.perf_counter()
    for _ in range(n):
        out = f(*args)
    out.block_until_ready()
    return (time.perf_counter() - t0) / n * 1e6


def run() -> list:
    key = jax.random.PRNGKey(0)
    rows = []
    # flash attention ref at serving-relevant sizes
    for (B, Hq, Hkv, S, D) in [(1, 8, 2, 1024, 64), (1, 8, 2, 2048, 64)]:
        ks = jax.random.split(key, 3)
        q = jax.random.normal(ks[0], (B, Hq, S, D))
        k = jax.random.normal(ks[1], (B, Hkv, S, D))
        v = jax.random.normal(ks[2], (B, Hkv, S, D))
        f = jax.jit(lambda q, k, v: R.flash_attention_ref(q, k, v))
        rows.append({"name": f"attn_ref_S{S}", "us_per_call": _time(f, q, k, v),
                     "derived": f"B{B}_Hq{Hq}_D{D}"})
    for (b, S, H, P, N) in [(1, 1024, 16, 64, 64)]:
        ks = jax.random.split(key, 5)
        x = jax.random.normal(ks[0], (b, S, H, P))
        dt = jax.nn.softplus(jax.random.normal(ks[1], (b, S, H)))
        A = -jnp.exp(jax.random.normal(ks[2], (H,)) * 0.5)
        B_ = jax.random.normal(ks[3], (b, S, N))
        C = jax.random.normal(ks[4], (b, S, N))
        f = jax.jit(lambda *a: R.ssd_scan_ref(*a))
        rows.append({"name": f"ssd_ref_S{S}", "us_per_call": _time(f, x, dt, A, B_, C),
                     "derived": f"H{H}_P{P}_N{N}"})
    write_csv("kernel_bench", rows)
    return rows
