"""Fig. 8: exploration overhead — % of queries spent rebalancing."""
from __future__ import annotations

from repro.core import PAPER_SETTINGS
from benchmarks.common import agg, write_csv


def run(rows) -> list:
    out = []
    for sched in ("odin_a10", "odin_a2", "lls"):
        for freq, dur in PAPER_SETTINGS:
            out.append({
                "scheduler": sched, "freq": freq, "dur": dur,
                "rebalance_pct": 100 * agg(rows, "serial_frac",
                                           scheduler=sched, freq=freq,
                                           dur=dur),
                "mean_mitigation_steps": agg(rows, "mean_mitigation",
                                             scheduler=sched, freq=freq,
                                             dur=dur),
            })
    write_csv("fig8_overhead", out)
    return out
