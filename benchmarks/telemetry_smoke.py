"""CI telemetry smoke: streaming summaries match dense within tolerance.

Runs the control-plane smoke scenario (bursty overload + ``slo_shed``
admission, docs/CONTROL.md) twice on each surface — once with the
default dense trace and once with ``trace_mode="streaming"``
(docs/TELEMETRY.md) — and gates that the constant-memory telemetry
path reports the same run:

* **single pipeline** (``simulate``): identical summary key set, exact
  offered/admitted/shed counts, p99-of-admitted within
  ``REPRO_TELEMETRY_P99_TOL`` (default 1%) relative error, SLO
  attainment within 0.5% absolute, goodput within 1% relative.
* **4-replica fleet** (``simulate_cluster``): the same gates on the
  merged fleet summary, with replica-scoped interference (freq=2,
  dur=100 on replica 2), ``odin`` rebalancing, ``odin_aware`` routing
  and ``load_profile`` autoscaling — so sketch *merging* across
  replicas is in the gated path, not just single-collector accuracy.

The streaming runs also drive a ``MemorySink`` and must emit at least
one metrics snapshot each.  Both summaries plus the per-key diffs land
in ``results/benchmarks/telemetry_smoke.json`` for the CI artifact
upload.

    REPRO_TELEMETRY_QUERIES=4000 PYTHONPATH=src \
        python -m benchmarks.telemetry_smoke
"""
from __future__ import annotations

import dataclasses
import json
import math
import os
import sys

from benchmarks.common import RESULTS_DIR, db_for
from repro.cluster import simulate_cluster
from repro.core import generate_events, simulate
from repro.telemetry import MemorySink

NUM_QUERIES = int(os.environ.get("REPRO_TELEMETRY_QUERIES", "4000"))
P99_TOL = float(os.environ.get("REPRO_TELEMETRY_P99_TOL", "0.01"))
NUM_EPS = 4
NUM_REPLICAS = 4
VICTIM = 2
SLO_SERVICES = 3.0

#: summary keys that must match exactly (counts and run bookkeeping).
EXACT_KEYS = ("num_shed", "shed_rate", "rebalances", "slo_latency_s")
#: (key, relative tolerance) pairs for the sketch-backed tails; the
#: p99 gate is the acceptance criterion, the rest catch gross drift.
REL_KEYS = (
    ("p99_latency_s", None),  # None -> P99_TOL
    ("p50_latency_s", 0.02),
    ("mean_latency_s", 1e-9),
    ("goodput_qps", 0.01),
    ("offered_load_qps", 1e-9),
    ("achieved_load_qps", 1e-9),
)
#: absolute-tolerance keys (already-normalized rates).
ABS_KEYS = (("slo_attainment", 0.005),)


def _rel(a: float, b: float) -> float:
    if math.isnan(a) and math.isnan(b):
        return 0.0
    if a == b:
        return 0.0
    return abs(a - b) / max(abs(a), abs(b), 1e-12)


def check_pair(scope: str, dense: dict, stream: dict, failures: list) -> dict:
    """Gate one dense/streaming summary pair; return the diff record."""
    diffs = {"scope": scope, "dense": dense, "streaming": stream,
             "key_sets_equal": set(dense) == set(stream)}
    if not diffs["key_sets_equal"]:
        failures.append(
            f"{scope}: summary key sets differ "
            f"({sorted(set(dense) ^ set(stream))})")
        return diffs
    rel_report = {}
    for key in EXACT_KEYS:
        if key in dense and float(dense[key]) != float(stream[key]):
            failures.append(f"{scope}: {key} diverged "
                            f"(dense {dense[key]} vs "
                            f"streaming {stream[key]})")
    for key, tol in REL_KEYS:
        tol = P99_TOL if tol is None else tol
        rel = _rel(float(dense[key]), float(stream[key]))
        rel_report[key] = rel
        if rel > tol:
            failures.append(f"{scope}: {key} rel err {rel:.4f} > {tol}")
    for key, tol in ABS_KEYS:
        err = abs(float(dense[key]) - float(stream[key]))
        rel_report[key] = err
        if err > tol:
            failures.append(f"{scope}: {key} abs err {err:.4f} > {tol}")
    diffs["errors"] = rel_report
    return diffs


def main() -> int:
    db = db_for("vgg16")
    probe = simulate(db, NUM_EPS, scheduler="none", events=[],
                     num_queries=10)
    cap = probe.peak_throughput
    slo = SLO_SERVICES * float(probe.service_latencies[-1])
    failures: list = []
    records = []

    # -- single pipeline ---------------------------------------------------
    pipe_kw = dict(
        num_queries=NUM_QUERIES, scheduler="none", events=[],
        workload="bursty",
        workload_kwargs=dict(burst_rate=3.0 * cap, base_rate=0.5 * cap,
                             mean_burst=2000.0 / cap, mean_gap=1000.0 / cap,
                             seed=7),
        admission="slo_shed", admission_kwargs=dict(slo=slo))
    dense = simulate(db, NUM_EPS, **pipe_kw)
    sink = MemorySink()
    stream = simulate(db, NUM_EPS, trace_mode="streaming",
                      metrics_sink=sink, sink_interval=1000, **pipe_kw)
    records.append(check_pair("pipeline", dense.summary(), stream.summary(),
                              failures))
    if len(sink) == 0:
        failures.append("pipeline: streaming run emitted no snapshots")
    records[-1]["sink_emissions"] = len(sink)

    # -- 4-replica fleet ---------------------------------------------------
    fleet_events = [
        dataclasses.replace(ev, replica=VICTIM)
        for ev in generate_events(
            NUM_QUERIES // NUM_REPLICAS, NUM_EPS, db.num_scenarios, 2,
            100, 5)
    ]
    fleet_kw = dict(
        scheduler="odin", alpha=10, num_queries=NUM_QUERIES,
        events=fleet_events, router="odin_aware", workload="bursty",
        workload_kwargs=dict(burst_rate=2.0 * NUM_REPLICAS * cap,
                             base_rate=0.375 * NUM_REPLICAS * cap,
                             mean_burst=80.0 / cap, mean_gap=250.0 / cap,
                             seed=6),
        admission="slo_shed", admission_kwargs=dict(slo=slo),
        autoscaler="load_profile")
    dense_ct = simulate_cluster(db, NUM_EPS, NUM_REPLICAS, **fleet_kw)
    fleet_sink = MemorySink()
    stream_ct = simulate_cluster(db, NUM_EPS, NUM_REPLICAS,
                                 trace_mode="streaming",
                                 metrics_sink=fleet_sink,
                                 sink_interval=1000, **fleet_kw)
    records.append(check_pair("fleet", dense_ct.summary(),
                              stream_ct.summary(), failures))
    if len(fleet_sink) == 0:
        failures.append("fleet: streaming run emitted no snapshots")
    records[-1]["sink_emissions"] = len(fleet_sink)

    os.makedirs(RESULTS_DIR, exist_ok=True)
    path = os.path.join(RESULTS_DIR, "telemetry_smoke.json")
    with open(path, "w") as f:
        json.dump({"schema": 1, "num_queries": NUM_QUERIES,
                   "p99_tolerance": P99_TOL, "records": records,
                   "failures": failures}, f, indent=2, default=repr)

    for rec in records:
        errs = rec.get("errors", {})
        print(f"{rec['scope']:9s} p99 dense "
              f"{rec['dense']['p99_latency_s']:10.2f}  streaming "
              f"{rec['streaming']['p99_latency_s']:10.2f}  "
              f"rel {errs.get('p99_latency_s', float('nan')):.5f}  "
              f"sink emits {rec['sink_emissions']}")
    if failures:
        print("telemetry_smoke FAILED: " + "; ".join(failures))
        return 1
    print(f"telemetry_smoke OK -> {path}")
    return 0


if __name__ == "__main__":
    sys.exit(main())
