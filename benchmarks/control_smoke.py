"""CI control-plane smoke: overload survives only with the control plane.

Drives a bursty overload (offered load well above capacity during
bursts) through the admission-control registry on two surfaces and
gates that SLO-aware shedding does what docs/CONTROL.md promises:

* **single pipeline** (``simulate``): admission ``none`` lets the
  arrival queue grow without bound and p99 blows through the SLO;
  ``slo_shed`` must hold p99-of-admitted within the SLO; ``queue_cap``
  is reported for comparison (bounded queue, but SLO-blind).
* **4-replica fleet** (``simulate_cluster``): the same overload with
  the paper's heaviest interference setting (freq=2, dur=100) scoped
  to replica 2, ``odin`` rebalancing per replica, and ``load_profile``
  autoscaling sizing the active set.  ``slo_shed`` must again hold
  p99-of-admitted within the (small-margin) SLO where ``none``
  violates it, and the autoscaler must actually move the active set.

All rows land in ``results/benchmarks/control_smoke.csv`` for the CI
artifact upload.

    REPRO_CONTROL_QUERIES=4000 PYTHONPATH=src python -m benchmarks.control_smoke
"""
from __future__ import annotations

import csv
import dataclasses
import math
import os
import sys

from benchmarks.common import RESULTS_DIR, db_for
from repro import api
from repro.core import generate_events

NUM_QUERIES = int(os.environ.get("REPRO_CONTROL_QUERIES", "4000"))
NUM_EPS = 4
NUM_REPLICAS = 4
VICTIM = 2
#: Latency objective, in multiples of the steady pipelined service
#: latency: one service plus a two-service queueing budget.
SLO_SERVICES = 3.0
#: Fleet gate headroom: replica-scoped interference can begin between
#: an admission decision and the query's execution, so a small tail of
#: admitted queries may land past the SLO (docs/CONTROL.md).
FLEET_P99_MARGIN = 1.05


def trace_row(scope: str, admission: str, autoscaler: str, trace) -> dict:
    s = trace.summary()
    row = {
        "scope": scope,
        "admission": admission,
        "autoscaler": autoscaler,
        "num_queries": NUM_QUERIES,
        "slo": s["slo_latency_s"],
        "p99_latency": s["p99_latency_s"],
        "mean_queue_delay": s["mean_queue_delay_s"],
        "shed_rate": s["shed_rate"],
        "slo_attainment": s["slo_attainment"],
        "goodput_qps": s["goodput_qps"],
        "offered_load": s["offered_load_qps"],
        "achieved_load": s["achieved_load_qps"],
        "mean_active_replicas": s.get("mean_active_replicas", 1.0),
    }
    return row


def main() -> int:
    db = db_for("vgg16")
    # One declaration per run (docs/API.md); the sweeps below swap only
    # the admission/autoscaler fields.
    probe = api.run(api.RunSpec(
        db=db, num_eps=NUM_EPS, num_queries=10, events=(),
        scheduler=api.SchedulerSpec(name="none")))
    cap = probe.peak_throughput
    service = float(probe.service_latencies[-1])
    slo = SLO_SERVICES * service
    workload_kwargs = dict(
        burst_rate=3.0 * cap,
        base_rate=0.5 * cap,
        mean_burst=2000.0 / cap,
        mean_gap=1000.0 / cap,
        seed=7,
    )

    rows, p99, attain = [], {}, {}
    # -- single pipeline: none vs queue_cap vs slo_shed -------------------
    for admission, admission_kwargs in (
        ("none", {}),
        ("queue_cap", dict(cap=8)),
        ("slo_shed", dict(slo=slo)),
    ):
        t = api.run(api.RunSpec(
            db=db, num_eps=NUM_EPS, num_queries=NUM_QUERIES, events=(),
            scheduler=api.SchedulerSpec(name="none"),
            workload=api.WorkloadSpec(name="bursty",
                                      kwargs=workload_kwargs),
            admission=api.AdmissionSpec(name=admission,
                                        kwargs=admission_kwargs)))
        p99[admission] = t.tail_latency(99)
        attain[admission] = t.slo_attainment
        rows.append(trace_row("pipeline", admission, "static", t))
        print(
            f"pipeline {admission:10s} p99 {p99[admission]:10.2f}  "
            f"shed {t.shed_rate:5.1%}  "
            f"attainment(slo={slo:.0f}) "
            f"{float((t.latencies <= slo).mean()):.3f}"
        )

    # -- 4-replica fleet: interference + autoscaling -----------------------
    fleet_events = [
        dataclasses.replace(ev, replica=VICTIM)
        for ev in generate_events(
            NUM_QUERIES // NUM_REPLICAS, NUM_EPS, db.num_scenarios, 2, 100, 5
        )
    ]
    # Burst/gap lengths give the run several ON/OFF cycles, so the
    # autoscaler sees both regimes: overload bursts that need the whole
    # fleet and quiet phases where ~half of it suffices.
    fleet_wl = dict(
        burst_rate=2.0 * NUM_REPLICAS * cap,
        base_rate=0.375 * NUM_REPLICAS * cap,
        mean_burst=80.0 / cap,
        mean_gap=250.0 / cap,
        seed=6,
    )
    fleet_p99, fleet_active = {}, {}
    for admission, admission_kwargs, autoscaler in (
        ("none", {}, None),
        ("slo_shed", dict(slo=slo), "load_profile"),
    ):
        ct = api.run(api.RunSpec(
            db=db, num_eps=NUM_EPS, num_queries=NUM_QUERIES,
            events=fleet_events,
            scheduler=api.SchedulerSpec(name="odin", alpha=10),
            workload=api.WorkloadSpec(name="bursty", kwargs=fleet_wl),
            admission=api.AdmissionSpec(name=admission,
                                        kwargs=admission_kwargs),
            cluster=api.ClusterSpec(num_replicas=NUM_REPLICAS,
                                    router="odin_aware",
                                    autoscaler=autoscaler)))
        fleet = ct.fleet
        fleet_p99[admission] = fleet.tail_latency(99)
        fleet_active[admission] = ct.summary()["mean_active_replicas"]
        rows.append(trace_row("fleet", admission, autoscaler or "static", fleet))
        rows[-1]["mean_active_replicas"] = fleet_active[admission]
        print(
            f"fleet    {admission:10s} p99 {fleet_p99[admission]:10.2f}  "
            f"shed {ct.shed_rate:5.1%}  "
            f"mean active {fleet_active[admission]:.2f}  "
            f"attainment(slo={slo:.0f}) "
            f"{float((fleet.latencies <= slo).mean()):.3f}"
        )

    os.makedirs(RESULTS_DIR, exist_ok=True)
    path = os.path.join(RESULTS_DIR, "control_smoke.csv")
    with open(path, "w", newline="") as f:
        w = csv.DictWriter(f, fieldnames=list(rows[0].keys()))
        w.writeheader()
        w.writerows(rows)

    failed = []
    if not all(
        math.isfinite(r["p99_latency"]) and math.isfinite(r["goodput_qps"])
        for r in rows
    ):
        failed.append("non-finite metrics in rows")
    if p99["none"] <= slo:
        failed.append(
            f"pipeline none p99 {p99['none']:.2f} <= slo {slo:.2f} "
            f"(overload too light to gate on)"
        )
    if p99["slo_shed"] > slo:
        failed.append(
            f"pipeline slo_shed p99-of-admitted {p99['slo_shed']:.2f} "
            f"> slo {slo:.2f}"
        )
    if attain["slo_shed"] < 0.999:
        failed.append(f"pipeline slo_shed attainment {attain['slo_shed']:.4f} < 0.999")
    if fleet_p99["none"] <= slo:
        failed.append(f"fleet none p99 {fleet_p99['none']:.2f} <= slo {slo:.2f}")
    if fleet_p99["slo_shed"] > FLEET_P99_MARGIN * slo:
        failed.append(
            f"fleet slo_shed p99-of-admitted {fleet_p99['slo_shed']:.2f} "
            f"> {FLEET_P99_MARGIN} * slo {slo:.2f}"
        )
    if not fleet_active["slo_shed"] < NUM_REPLICAS:
        failed.append(
            f"load_profile autoscaler never drained a replica "
            f"(mean active {fleet_active['slo_shed']:.2f})"
        )
    if failed:
        print("control_smoke FAILED: " + "; ".join(failed))
        return 1
    print(f"control_smoke OK -> {path}")
    return 0


if __name__ == "__main__":
    sys.exit(main())
