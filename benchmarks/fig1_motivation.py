"""Fig. 1: motivating example — 4-stage VGG16 pipeline, interference on the
stage-4 EP; static-3-stage vs dynamic rebalance vs exhaustive optimum."""
from __future__ import annotations

import time

from repro.core import (
    SimTimeSource,
    odin_rebalance,
    optimal_partition,
    synthetic_database,
    throughput,
)
from benchmarks.common import write_csv


def run() -> list:
    db = synthetic_database("vgg16")
    base_cfg, peak = optimal_partition(db, [0] * 4, 4)
    scen = [0, 0, 0, 9]                      # colocated workload on EP 4
    src = SimTimeSource(db, scen)
    degraded = throughput(src.stage_times(base_cfg))

    # static: give EP4 away, re-balance on 3 EPs
    cfg3, t3 = optimal_partition(db, scen[:3], 3)

    # dynamic: ODIN rebalance on all 4 EPs
    t0 = time.perf_counter()
    res = odin_rebalance(base_cfg, 10, src)
    odin_wall = time.perf_counter() - t0

    # exhaustive (paper: 42.5 min; our DP oracle: ms)
    t0 = time.perf_counter()
    cfg_opt, t_opt = optimal_partition(db, scen, 4)
    oracle_wall = time.perf_counter() - t0

    rows = [
        {"config": "balanced_4stage_clean", "throughput": peak,
         "loss_vs_peak_pct": 0.0, "search_wall_s": 0.0},
        {"config": "balanced_4stage_interfered", "throughput": degraded,
         "loss_vs_peak_pct": 100 * (1 - degraded / peak), "search_wall_s": 0.0},
        {"config": "static_3stage", "throughput": t3,
         "loss_vs_peak_pct": 100 * (1 - t3 / peak), "search_wall_s": 0.0},
        {"config": "odin_rebalanced", "throughput": res.throughput,
         "loss_vs_peak_pct": 100 * (1 - res.throughput / peak),
         "search_wall_s": odin_wall},
        {"config": "exhaustive_optimum", "throughput": t_opt,
         "loss_vs_peak_pct": 100 * (1 - t_opt / peak),
         "search_wall_s": oracle_wall},
    ]
    write_csv("fig1_motivation", rows)
    return rows
