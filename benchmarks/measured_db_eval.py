"""ODIN vs LLS on the *measured* interference database
(results/measured_db.json, built by tools/build_measured_db.py with real
co-located stressor processes — the paper's own §3.3 protocol executed on
this container as the 'real platform')."""
from __future__ import annotations

import os

import numpy as np

from repro.core import LayerDatabase, PAPER_SETTINGS, simulate
from benchmarks.common import write_csv

DB_PATH = os.environ.get("REPRO_MEASURED_DB", "results/measured_db.json")


def run() -> list:
    if not os.path.exists(DB_PATH):
        return []
    db = LayerDatabase.load(DB_PATH)
    rows = []
    for name, kw in (("odin_a10", dict(scheduler="odin", alpha=10)),
                     ("odin_a2", dict(scheduler="odin", alpha=2)),
                     ("lls", dict(scheduler="lls")),
                     ("hybrid", dict(scheduler="hybrid", alpha=10)),
                     ("none", dict(scheduler="none"))):
        for f, d in PAPER_SETTINGS:
            for seed in (0, 1, 2):
                r = simulate(db, 4, num_queries=1200, freq_period=f,
                             duration=d, seed=seed, **kw)
                rows.append({
                    "scheduler": name, "freq": f, "dur": d, "seed": seed,
                    "mean_latency": r.latencies.mean(),
                    "p99_latency": r.tail_latency(),
                    "steady_throughput": r.steady_throughput,
                    "mean_throughput": r.throughputs.mean(),
                })
    write_csv("measured_db_eval", rows)
    return rows


def summarize(rows) -> dict:
    def m(sched, key):
        vals = [r[key] for r in rows if r["scheduler"] == sched]
        return float(np.mean(vals))
    return {
        "throughput_gain_pct":
            100 * (m("odin_a10", "steady_throughput")
                   / m("lls", "steady_throughput") - 1),
        "latency_gain_pct":
            100 * (1 - m("odin_a10", "mean_latency")
                   / m("lls", "mean_latency")),
        "tail_gain_pct":
            100 * (1 - m("odin_a10", "p99_latency")
                   / m("lls", "p99_latency")),
    }
