"""Ablation: ODIN's exploration budget alpha (paper only reports 2 and 10)."""
from __future__ import annotations

import numpy as np

from repro.core import PAPER_SETTINGS, simulate, synthetic_database
from benchmarks.common import write_csv

ALPHAS = (1, 2, 4, 10, 20)


def run() -> list:
    db = synthetic_database("vgg16")
    rows = []
    for alpha in ALPHAS:
        lat, thr, tail, ser = [], [], [], []
        for f, d in PAPER_SETTINGS:
            for seed in (0, 1):
                r = simulate(db, 4, scheduler="odin", alpha=alpha,
                             num_queries=1000, freq_period=f, duration=d,
                             seed=seed)
                lat.append(r.latencies.mean())
                thr.append(r.steady_throughput)
                tail.append(r.tail_latency())
                ser.append(r.rebalance_fraction)
        rows.append({"alpha": alpha,
                     "mean_latency": float(np.mean(lat)),
                     "steady_throughput": float(np.mean(thr)),
                     "p99_latency": float(np.mean(tail)),
                     "serial_frac": float(np.mean(ser))})
    write_csv("ablation_alpha", rows)
    return rows
