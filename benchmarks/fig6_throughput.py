"""Fig. 6: throughput distribution, ODIN vs LLS (reuses the Fig. 5 matrix)."""
from __future__ import annotations

from benchmarks.common import agg, write_csv


def run(rows) -> list:
    write_csv("fig6_throughput", rows)
    return rows


def summarize(rows) -> dict:
    """Steady-state (pipeline operating) throughput — the paper's Fig. 6
    metric; exploration overhead is reported separately in Fig. 8."""
    out = {}
    for sched in ("odin_a10", "odin_a2", "lls"):
        out[sched] = agg(rows, "steady_throughput", scheduler=sched)
        out[sched + "_incl_exploration"] = agg(rows, "mean_throughput",
                                               scheduler=sched)
    out["odin_a10_vs_lls_pct"] = 100 * (out["odin_a10"] / out["lls"] - 1)
    out["odin_a2_vs_lls_pct"] = 100 * (out["odin_a2"] / out["lls"] - 1)
    out["odin_a10_vs_lls_incl_exploration_pct"] = 100 * (
        out["odin_a10_incl_exploration"]
        / out["lls_incl_exploration"] - 1)
    return out
