"""Perf benchmark + regression gate for the batch-granular fast path.

Times identical ``run_matrix`` rows through the scalar per-query tick
(``chunking=False``) and the chunked fast path (``chunking=True``),
verifies the closed-loop summaries are bit-identical, and emits
``BENCH_runner.json`` — the perf-trajectory artifact this and future
perf PRs diff against.  Exits non-zero when the steady-state row's
speedup falls below the gate (CI runs this next to the smoke benchmark).

    PYTHONPATH=src python -m benchmarks.runner_bench

Environment:
    REPRO_BENCH_QUERIES        queries per row            (default 2000)
    REPRO_BENCH_REPEATS        best-of repeats per row    (default 3)
    REPRO_BENCH_MIN_SPEEDUP    gate on the steady row     (default 5.0)
    REPRO_BENCH_SCALE_QUERIES  dense scalability-row size (default 1000000;
                               0 skips the row)
    REPRO_BENCH_STREAM_QUERIES streaming scalability-row size
                               (default 10000000; 0 skips the row)
    REPRO_BENCH_RSS_TOLERANCE  streaming-RSS gate: streaming peak RSS
                               must stay within this multiple of the
                               dense 1M row's (default 1.5)
    REPRO_BENCH_BATCH_RATIO    continuous-batching gate: continuous
                               mean queue delay must beat drain by at
                               least this factor (default 1.3)

Besides the scalar-vs-chunked comparison rows, the report carries two
*scalability* rows: a 1M-query dense open-loop run through the
vectorized arrival/queue/completion ledger, and a 10M-query run in
``trace_mode="streaming"`` (docs/TELEMETRY.md) whose peak RSS must stay
flat — within ``REPRO_BENCH_RSS_TOLERANCE`` of the 10x-smaller dense
row — because the streaming collector folds every flushed chunk into
constant-memory sketches and rollups instead of dense per-query arrays.
Each scale row runs in its own subprocess (``--scale-row``): ru_maxrss
is a process-lifetime high-water mark, so an in-process measurement
would inherit whichever earlier row peaked highest.

A fourth comparison — the ``sharding`` section — re-runs the
scalar-vs-chunked pair with a device mesh armed (docs/SHARDING.md): the
``bursty_sharding`` row drives the mesh-event smoke scenario through
both paths and gates that the chunked fast path stays bit-identical
with slice moves and mesh events in play, and that the mesh-aware
explorer commits at least one resize.

A third comparison — the ``batching`` section — runs a bursty
mixed-length open-loop workload through drain-mode and continuous
formed dispatch (docs/WORKLOADS.md "Continuous batching & length
buckets") at the same offered load and gates on continuous winning:
its mean queue delay must be at least ``REPRO_BENCH_BATCH_RATIO``
(default 1.3) times lower than drain's, with a p99 queue delay no
worse.  The simulator is deterministic, so the row is exactly
reproducible across hosts.

The gate row (``steady_none``) is the fast path's home turf: long
environment-steady segments with no exploration phases, where the run
is dominated by the per-query tick the chunking removes.  The ODIN/LLS
rows are reported (not gated): their runs interleave serial exploration
phases — which are inherently per-query — so their speedups measure
the steady fraction, not the fast path itself.
"""
from __future__ import annotations

import json
import math
import os
import resource
import subprocess
import sys
import time

import numpy as np

from benchmarks.common import RESULTS_DIR, db_for, run_matrix
from repro.core import InterferenceEvent, generate_events, simulate

NUM_QUERIES = int(os.environ.get("REPRO_BENCH_QUERIES", "2000"))
REPEATS = int(os.environ.get("REPRO_BENCH_REPEATS", "3"))
MIN_SPEEDUP = float(os.environ.get("REPRO_BENCH_MIN_SPEEDUP", "5.0"))
SCALE_QUERIES = int(os.environ.get("REPRO_BENCH_SCALE_QUERIES", "1000000"))
STREAM_QUERIES = int(os.environ.get("REPRO_BENCH_STREAM_QUERIES",
                                    "10000000"))
RSS_TOLERANCE = float(os.environ.get("REPRO_BENCH_RSS_TOLERANCE", "1.5"))
BATCH_MIN_RATIO = float(os.environ.get("REPRO_BENCH_BATCH_RATIO", "1.3"))
GATE_ROW = "steady_none"

#: (row name, run_matrix scheduler spec, (freq, dur) paper setting)
ROWS = (
    ("steady_none", dict(scheduler="none"), (100, 100)),
    ("odin_a10", dict(scheduler="odin", alpha=10), (100, 100)),
    ("lls", dict(scheduler="lls"), (100, 10)),
)

#: run_matrix columns that must be bit-identical between the two paths
#: on a closed-loop row (NaN-valued columns compare as both-NaN).
SUMMARY_KEYS = (
    "mean_latency", "p50_latency", "p99_latency", "mean_throughput",
    "steady_throughput", "peak_throughput", "rebalances", "serial_frac",
    "mean_mitigation", "mean_queue_delay", "p99_queue_delay",
    "max_queue_depth", "offered_load", "achieved_load",
)


def _summaries_identical(a: dict, b: dict) -> bool:
    for k in SUMMARY_KEYS:
        x, y = float(a[k]), float(b[k])
        if math.isnan(x) and math.isnan(y):
            continue
        if x != y:
            return False
    return True


def bench_row(name: str, spec: dict, setting) -> dict:
    kw = dict(schedulers={name: spec}, settings=(setting,), seeds=(0,),
              num_queries=NUM_QUERIES)
    walls = {False: [], True: []}
    rows = {}
    for _ in range(REPEATS):
        for chunking in (False, True):
            out = run_matrix("vgg16", chunking=chunking, **kw)
            assert len(out) == 1
            walls[chunking].append(out[0]["sim_wall_s"])
            rows[chunking] = out[0]
    scalar_s, chunked_s = min(walls[False]), min(walls[True])
    identical = _summaries_identical(rows[False], rows[True])
    return {
        "row": name,
        "freq": setting[0],
        "dur": setting[1],
        "num_queries": NUM_QUERIES,
        "scalar_s": scalar_s,
        "chunked_s": chunked_s,
        "scalar_qps": NUM_QUERIES / scalar_s,
        "chunked_qps": NUM_QUERIES / chunked_s,
        "speedup": scalar_s / chunked_s,
        "summaries_identical": identical,
    }


def bench_batching() -> dict:
    """Drain vs continuous formed dispatch on a bursty mixed-length row.

    Both modes see the identical arrival process, length stream and
    dispatch cost model (per-dispatch ``batch_overhead`` plus
    length-scaled stage work); the only difference is whether arrivals
    may join the in-flight batch at stage boundaries.  Burstiness is
    what continuous batching monetizes: a burst landing just after a
    dispatch forms rides along instead of waiting out the whole
    group-synchronous drain.
    """
    db = db_for("vgg16")
    out = {}
    for mode in ("drain", "continuous"):
        t0 = time.perf_counter()
        r = simulate(db, 8, scheduler="none", events=[],
                     num_queries=800, workload="bursty",
                     workload_kwargs=dict(rate=0.0035, burst_rate=0.007,
                                          burst_prob=0.05, seed=7),
                     batching=mode, max_batch=16, buckets="pow2:64:512",
                     lengths="bimodal",
                     lengths_kwargs=dict(short=48, long=420, p_long=0.1,
                                         seed=11),
                     batch_overhead=30.0)
        s = r.summary()
        out[mode] = {
            "mean_queue_delay": s["mean_queue_delay_s"],
            "p99_queue_delay": s["p99_queue_delay_s"],
            "mean_batch_occupancy": s["mean_batch_occupancy"],
            "padded_token_frac": s["padded_token_frac"],
            "achieved_load": s["achieved_load_qps"],
            "sim_wall_s": time.perf_counter() - t0,
        }
    ratio = (out["drain"]["mean_queue_delay"]
             / max(out["continuous"]["mean_queue_delay"], 1e-12))
    return {
        "row": "bursty_batching",
        "num_queries": 800,
        "workload": "bursty",
        "max_batch": 16,
        "buckets": "pow2:64:512",
        "lengths": "bimodal",
        "drain": out["drain"],
        "continuous": out["continuous"],
        "delay_ratio": ratio,
    }


def bench_sharding() -> dict:
    """Scalar vs chunked fast path with a device mesh armed.

    The ``bursty_sharding`` row runs the docs/SHARDING.md smoke scenario
    (vgg16 over an 8-device mesh with heavy collective costs, one
    ``kind="mesh"`` event inflating collective time mid-run) under a
    bursty arrival process, through both the scalar per-query tick and
    the chunked fast path.  The chunked path must cut steady chunks on
    mesh-event edges exactly like the scalar tick: the whole mesh
    surface (configs, slice assignments, collective fractions, resize
    count) stays bit-identical, latencies within the open-loop ledger
    tolerance (tests/test_batching.py: the vectorized arrival cumsum
    reorders float additions) — and the mesh-aware explorer must commit
    at least one slice move.
    """
    from repro import api

    db = db_for("vgg16")
    n = 800
    mesh = api.MeshSpec(devices=8, coll_cost=0.5)
    evs = list(generate_events(n, 4, 12, 20, 10, seed=3))
    evs.append(InterferenceEvent(start=n // 3, duration=n // 4, ep=0,
                                 scenario=0, kind="mesh", factor=6.0))
    cap = api.run(api.RunSpec(
        db=db, num_eps=4, num_queries=10, events=(), mesh=mesh,
        scheduler=api.SchedulerSpec(name="none"))).peak_throughput
    base = api.RunSpec(
        db=db, num_eps=4, num_queries=n, events=evs, mesh=mesh,
        scheduler=api.SchedulerSpec(name="odin"),
        workload=api.WorkloadSpec(
            name="bursty",
            kwargs=dict(burst_rate=2.0 * cap, base_rate=0.5 * cap,
                        mean_burst=3000.0, mean_gap=5000.0, seed=7)))

    walls = {False: [], True: []}
    traces = {}
    for _ in range(REPEATS):
        for chunking in (False, True):
            t0 = time.perf_counter()
            t = api.run(base.replace(
                batching=api.BatchingSpec(chunking=chunking)))
            walls[chunking].append(time.perf_counter() - t0)
            traces[chunking] = t
    scalar_s, chunked_s = min(walls[False]), min(walls[True])
    a, b = traces[False], traces[True]
    identical = (
        a.mesh_trace == b.mesh_trace
        and a.configs_trace == b.configs_trace
        and bool(np.array_equal(a.collective_fracs, b.collective_fracs))
        and a.num_mesh_resizes == b.num_mesh_resizes
        and a.num_rebalances == b.num_rebalances
        and bool(np.allclose(a.latencies, b.latencies, rtol=1e-9,
                             atol=0.0)))
    s = b.summary()
    return {
        "row": "bursty_sharding",
        "num_queries": n,
        "workload": "bursty",
        "mesh_devices": mesh.devices,
        "coll_cost": mesh.coll_cost,
        "mesh_factor": 6.0,
        "scalar_s": scalar_s,
        "chunked_s": chunked_s,
        "speedup": scalar_s / chunked_s,
        "paths_consistent": identical,
        "num_mesh_resizes": b.num_mesh_resizes,
        "mean_collective_frac": s["mean_collective_frac"],
        "p99_latency": s["p99_latency_s"],
    }


def _peak_rss_mb() -> float:
    """Process peak resident set size, MB (ru_maxrss is KB on Linux)."""
    return resource.getrusage(resource.RUSAGE_SELF).ru_maxrss / 1024.0


def bench_scale(num_queries: int, trace_mode: str = "dense") -> dict:
    """One open-loop scale run through the vectorized ledger.

    No interference events and a static scheduler: the row isolates the
    arrival/queue/completion ledger (cumsum admission, pruned-heap
    depth accounting) — the pieces that must stay O(n log n) with flat
    memory at fleet scale.  Offered load sits just under capacity so
    the queue stays busy without diverging.  ``trace_mode="streaming"``
    runs the same workload through the constant-memory telemetry
    collector (``repro.telemetry``) instead of dense per-query arrays.
    """
    db = db_for("vgg16")
    cap = simulate(db, 4, scheduler="none", events=[],
                   num_queries=10).peak_throughput
    t0 = time.perf_counter()
    r = simulate(db, 4, scheduler="none", events=[],
                 num_queries=num_queries, workload="poisson",
                 workload_kwargs=dict(rate=0.9 * cap, seed=0),
                 trace_mode=trace_mode)
    wall = time.perf_counter() - t0
    s = r.summary()
    return {
        "row": ("scale_ledger" if trace_mode == "dense"
                else "scale_streaming"),
        "num_queries": num_queries,
        "workload": "poisson",
        "trace_mode": trace_mode,
        "chunked_s": wall,
        "chunked_qps": num_queries / wall,
        "peak_rss_mb": _peak_rss_mb(),
        "mean_queue_delay": s["mean_queue_delay_s"],
        "achieved_load": s["achieved_load_qps"],
        "finite": all(math.isfinite(float(s[k]))
                      for k in ("p99_latency_s", "mean_queue_delay_s",
                                "achieved_load_qps")),
    }


def _bench_scale_subprocess(num_queries: int, trace_mode: str) -> dict:
    """Run one scale row in a fresh interpreter and return its row dict.

    Isolation keeps ``ru_maxrss`` honest: it is a process-lifetime
    high-water mark, so rows sharing a process would all report
    whichever allocation peaked highest (the bit-identity rows touch
    dense 2k-query traces before any scale row runs).
    """
    out = subprocess.run(
        [sys.executable, "-m", "benchmarks.runner_bench",
         "--scale-row", trace_mode, str(num_queries)],
        capture_output=True, text=True, check=True,
        env=dict(os.environ), cwd=os.getcwd())
    return json.loads(out.stdout)


def main() -> int:
    if len(sys.argv) >= 4 and sys.argv[1] == "--scale-row":
        # Child mode: one scale row, JSON on stdout, nothing else.
        json.dump(bench_scale(int(sys.argv[3]), trace_mode=sys.argv[2]),
                  sys.stdout)
        return 0

    results = [bench_row(*row) for row in ROWS]
    batching = bench_batching()
    sharding = bench_sharding()
    scale = (_bench_scale_subprocess(SCALE_QUERIES, "dense")
             if SCALE_QUERIES > 0 else None)
    scale_streaming = (_bench_scale_subprocess(STREAM_QUERIES, "streaming")
                       if STREAM_QUERIES > 0 else None)
    report = {
        "schema": 1,
        "benchmark": "runner_fast_path",
        "model": "vgg16",
        "workload": "closed",
        "num_queries": NUM_QUERIES,
        "repeats": REPEATS,
        "timestamp": time.strftime("%Y-%m-%dT%H:%M:%SZ", time.gmtime()),
        "gate": {"row": GATE_ROW, "min_speedup": MIN_SPEEDUP,
                 "rss_tolerance": RSS_TOLERANCE,
                 "batch_min_ratio": BATCH_MIN_RATIO},
        "rows": results,
        "batching": batching,
        "sharding": sharding,
        "scale": scale,
        "scale_streaming": scale_streaming,
    }
    os.makedirs(RESULTS_DIR, exist_ok=True)
    path = os.path.join(RESULTS_DIR, "BENCH_runner.json")
    with open(path, "w") as f:
        json.dump(report, f, indent=2)

    failed = []
    for r in results:
        print(f"{r['row']:12s} ({r['freq']:3d},{r['dur']:3d}): "
              f"scalar {r['scalar_qps']:9.0f} q/s  "
              f"chunked {r['chunked_qps']:9.0f} q/s  "
              f"speedup {r['speedup']:5.1f}x  "
              f"{'bit-identical' if r['summaries_identical'] else 'DIVERGED'}")
        if not r["summaries_identical"]:
            failed.append(f"{r['row']}: summaries diverged between paths")
    gate = next(r for r in results if r["row"] == GATE_ROW)
    if gate["speedup"] < MIN_SPEEDUP:
        failed.append(f"{GATE_ROW}: speedup {gate['speedup']:.1f}x "
                      f"< gate {MIN_SPEEDUP:.1f}x")
    b = batching
    print(f"{b['row']:12s} drain qd {b['drain']['mean_queue_delay']:8.1f}  "
          f"continuous qd {b['continuous']['mean_queue_delay']:8.1f}  "
          f"ratio {b['delay_ratio']:5.2f}x  "
          f"p99 {b['drain']['p99_queue_delay']:.1f} -> "
          f"{b['continuous']['p99_queue_delay']:.1f}  "
          f"padded {100 * b['continuous']['padded_token_frac']:.0f}%")
    if b["delay_ratio"] < BATCH_MIN_RATIO:
        failed.append(f"{b['row']}: continuous/drain queue-delay ratio "
                      f"{b['delay_ratio']:.2f}x < gate "
                      f"{BATCH_MIN_RATIO:.1f}x")
    if (b["continuous"]["p99_queue_delay"]
            > b["drain"]["p99_queue_delay"]):
        failed.append(f"{b['row']}: continuous p99 queue delay "
                      f"{b['continuous']['p99_queue_delay']:.1f} worse "
                      f"than drain {b['drain']['p99_queue_delay']:.1f}")
    sh = sharding
    print(f"{sh['row']:12s} mesh {sh['mesh_devices']}dev: "
          f"scalar {sh['scalar_s']:6.2f}s  "
          f"chunked {sh['chunked_s']:6.2f}s  "
          f"speedup {sh['speedup']:5.1f}x  "
          f"resizes {sh['num_mesh_resizes']:3d}  "
          f"{'consistent' if sh['paths_consistent'] else 'DIVERGED'}")
    if not sh["paths_consistent"]:
        failed.append(f"{sh['row']}: mesh-armed chunked path diverged "
                      f"from the scalar tick")
    if sh["num_mesh_resizes"] < 1:
        failed.append(f"{sh['row']}: odin committed no mesh resize")
    for row in (scale, scale_streaming):
        if row is None:
            continue
        print(f"{row['row']:12s} {row['num_queries']} queries "
              f"({row['workload']}, {row['trace_mode']}): "
              f"{row['chunked_s']:6.2f}s  "
              f"{row['chunked_qps']:9.0f} q/s  "
              f"peak RSS {row['peak_rss_mb']:7.1f} MB")
        if not row["finite"]:
            failed.append(f"{row['row']}: non-finite summary metrics")
    if scale is not None and scale_streaming is not None:
        # The flat-memory gate: 10x the queries in streaming mode may
        # not cost more than RSS_TOLERANCE x the dense row's memory.
        budget = RSS_TOLERANCE * scale["peak_rss_mb"]
        if scale_streaming["peak_rss_mb"] > budget:
            failed.append(
                f"scale_streaming: peak RSS "
                f"{scale_streaming['peak_rss_mb']:.1f} MB > "
                f"{RSS_TOLERANCE:.2f}x dense row "
                f"({scale['peak_rss_mb']:.1f} MB)")
    if failed:
        print("runner_bench FAILED: " + "; ".join(failed))
        return 1
    print(f"runner_bench OK -> {path}")
    return 0


if __name__ == "__main__":
    sys.exit(main())
