"""Fig. 9: QoS — SLO violations vs SLO level (throughput SLO, w.r.t. peak
and w.r.t. the resource-constrained exhaustive-search optimum)."""
from __future__ import annotations

import numpy as np

from repro.core import PAPER_SETTINGS, simulate
from benchmarks.common import MODELS, NUM_EPS, NUM_QUERIES, SEEDS, db_for, write_csv

SLO_LEVELS = (1.0, 0.95, 0.9, 0.85, 0.8, 0.75, 0.7, 0.6, 0.5, 0.4, 0.35)


def run() -> list:
    rows = []
    for model in MODELS:
        db = db_for(model)
        for sched, kw in (("odin_a10", dict(scheduler="odin", alpha=10)),
                          ("lls", dict(scheduler="lls"))):
            per_level = {lv: [] for lv in SLO_LEVELS}
            per_level_rc = {lv: [] for lv in SLO_LEVELS}
            for freq, dur in PAPER_SETTINGS:
                for seed in SEEDS[:2]:
                    r = simulate(db, NUM_EPS, num_queries=NUM_QUERIES // 2,
                                 freq_period=freq, duration=dur, seed=seed,
                                 **kw)
                    for lv in SLO_LEVELS:
                        per_level[lv].append(r.slo_violations(lv, "peak"))
                        per_level_rc[lv].append(
                            r.slo_violations(lv, "resource_constrained"))
            for lv in SLO_LEVELS:
                rows.append({
                    "model": model, "scheduler": sched, "slo_level": lv,
                    "violations_vs_peak": float(np.mean(per_level[lv])),
                    "violations_vs_rc": float(np.mean(per_level_rc[lv])),
                })
    write_csv("fig9_qos", rows)
    return rows
