"""CI soak smoke: diurnal traffic + replica churn at flat memory.

A miniature of the 10M-query soak run (docs/TELEMETRY.md,
docs/FAULTS.md): a 3-replica fleet serves a sinusoidal diurnal arrival
process while a deterministic churn plan (:func:`periodic_crashes`)
takes one replica down after another, with retries + circuit-breaker
routing carrying the traffic around each outage.  The run uses
``trace_mode="streaming"`` and drives two sinks:

* a :class:`ThresholdSink` paging on fleet availability dipping below
  ``AVAIL_PAGE`` (hysteresis-cleared at ``AVAIL_CLEAR``), and
* an RSS sampler that reads ``/proc/self/statm`` at every snapshot.

Gates:

* fleet availability >= ``AVAIL_GATE`` despite the churn,
* every query served (replica counts sum to the offered count),
* RSS growth from the first-quarter sample to the run's end below
  ``RSS_BOUND_MB`` (flat-memory telemetry — the soak must not
  accumulate per-query state), and
* at least ``MIN_WINDOWS`` occupied windowed-rollup buckets.

The summary, ThresholdSink incident log, RSS samples, and the
windowed offered/achieved rate profile land in
``results/benchmarks/soak_smoke.json`` for the CI artifact upload.

    REPRO_SOAK_QUERIES=3000 PYTHONPATH=src python -m benchmarks.soak_smoke
"""
from __future__ import annotations

import json
import os
import resource
import sys

from benchmarks.common import RESULTS_DIR, db_for
from repro.cluster import simulate_cluster
from repro.core import simulate
from repro.faults import periodic_crashes
from repro.telemetry import ThresholdSink

NUM_QUERIES = int(os.environ.get("REPRO_SOAK_QUERIES", "3000"))
NUM_REPLICAS = 3
UTILIZATION = 0.55        # mean offered load vs fleet peak
AVAIL_PAGE = 0.95         # ThresholdSink pages below this...
AVAIL_CLEAR = 0.97        # ...and re-arms above this (hysteresis)
AVAIL_GATE = 0.99         # hard gate on the final fleet availability
RSS_BOUND_MB = 64.0       # generous flat-memory bound
MIN_WINDOWS = 8


def _rss_mb() -> float:
    """Current resident set in MiB (Linux); peak-RSS fallback."""
    try:
        with open("/proc/self/statm") as f:
            pages = int(f.read().split()[1])
        return pages * resource.getpagesize() / 2**20
    except (OSError, IndexError, ValueError):
        return resource.getrusage(resource.RUSAGE_SELF).ru_maxrss / 1024.0


class RssSampler:
    """Forwards snapshots to an inner sink, sampling RSS per emit."""

    def __init__(self, inner):
        self.inner = inner
        self.samples = []

    def emit(self, snapshot):
        self.samples.append(_rss_mb())
        self.inner.emit(snapshot)


def main() -> int:
    db = db_for("vgg16")
    peak = simulate(db, NUM_REPLICAS, scheduler="none", events=[],
                    num_queries=10).peak_throughput
    mean_rate = UTILIZATION * NUM_REPLICAS * peak
    horizon = NUM_QUERIES / mean_rate
    churn = periodic_crashes(horizon, period=horizon / 8,
                             duration=horizon / 40,
                             num_replicas=NUM_REPLICAS, time_indexed=True)

    pager = ThresholdSink()
    pager.add_rule("repro_availability", AVAIL_PAGE, above=False,
                   clear=AVAIL_CLEAR)
    sink = RssSampler(pager)

    ct = simulate_cluster(
        db, NUM_REPLICAS, NUM_REPLICAS, scheduler="odin",
        num_queries=NUM_QUERIES, router="least_outstanding",
        workload="diurnal",
        workload_kwargs=dict(mean_rate=mean_rate, period=horizon / 2,
                             amplitude=0.6, seed=13),
        faults=churn,
        retries=dict(max_retries=4, backoff=2.0, jitter=0.5),
        health_kwargs=dict(failure_threshold=1, cooldown=horizon / 160),
        trace_mode="streaming", metrics_sink=sink,
        sink_interval=max(50, NUM_QUERIES // 30))

    s = ct.summary()
    starts, offered, achieved = ct.fleet.load_profile()
    quarter = sink.samples[max(0, len(sink.samples) // 4 - 1)]
    rss_growth = sink.samples[-1] - quarter
    print(f"soak: {NUM_QUERIES} queries, {len(churn.events)} crash "
          f"windows, avail {s['availability']:.4f}, "
          f"retried {s['num_retried']:.0f}, "
          f"downtime {s['downtime_s']:.0f}s, "
          f"p99 {s['p99_latency_s']:.1f}s, "
          f"rss growth {rss_growth:+.1f} MiB over "
          f"{len(sink.samples)} samples, "
          f"{len(pager.incidents)} availability incidents")

    os.makedirs(RESULTS_DIR, exist_ok=True)
    path = os.path.join(RESULTS_DIR, "soak_smoke.json")
    with open(path, "w") as f:
        json.dump({
            "num_queries": NUM_QUERIES,
            "num_replicas": NUM_REPLICAS,
            "crash_windows": len(churn.events),
            "summary": s,
            "incidents": pager.incidents,
            "rss_mb": sink.samples,
            "load_profile": {"window_starts": starts.tolist(),
                             "offered_qps": offered.tolist(),
                             "achieved_qps": achieved.tolist()},
        }, f, indent=2)

    failed = []
    if s["availability"] < AVAIL_GATE:
        failed.append(f"availability {s['availability']:.4f} "
                      f"< {AVAIL_GATE}")
    served = int(ct.replica_counts.sum())
    expected = NUM_QUERIES - int(s["num_failed"]) - int(s["num_shed"])
    if served != expected:
        failed.append(f"{served} served != {expected} "
                      "offered - failed - shed")
    if rss_growth > RSS_BOUND_MB:
        failed.append(f"RSS grew {rss_growth:.1f} MiB "
                      f"(bound {RSS_BOUND_MB}) — streaming telemetry "
                      "is accumulating per-query state")
    if len(starts) < MIN_WINDOWS:
        failed.append(f"only {len(starts)} rollup windows "
                      f"(need >= {MIN_WINDOWS})")
    if len(sink.samples) < 2:
        failed.append("metrics sink never fired")

    if failed:
        print("soak_smoke FAILED: " + "; ".join(failed))
        return 1
    print(f"soak_smoke OK -> {path}")
    return 0


if __name__ == "__main__":
    sys.exit(main())
