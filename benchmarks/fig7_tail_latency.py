"""Fig. 7: tail (p99) latency distribution, ODIN vs LLS."""
from __future__ import annotations

from benchmarks.common import agg, write_csv


def run(rows) -> list:
    write_csv("fig7_tail_latency", rows)
    return rows


def summarize(rows) -> dict:
    out = {}
    for sched in ("odin_a10", "odin_a2", "lls"):
        out[sched] = agg(rows, "p99_latency", scheduler=sched)
    out["odin_a10_vs_lls_pct"] = 100 * (1 - out["odin_a10"] / out["lls"])
    return out
