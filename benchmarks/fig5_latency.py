"""Fig. 5: latency distribution, ODIN(a=2,10) vs LLS, 9 (freq,dur) settings."""
from __future__ import annotations

from benchmarks.common import MODELS, agg, run_matrix, write_csv


def run() -> list:
    rows = []
    for model in MODELS:
        rows += run_matrix(model)
    write_csv("fig5_latency", rows)
    return rows


def summarize(rows) -> dict:
    out = {}
    for sched in ("odin_a10", "odin_a2", "lls"):
        out[sched] = agg(rows, "mean_latency", scheduler=sched)
    out["odin_a10_vs_lls_pct"] = 100 * (1 - out["odin_a10"] / out["lls"])
    out["odin_a2_vs_lls_pct"] = 100 * (1 - out["odin_a2"] / out["lls"])
    return out
