"""Fig. 10: scalability — ResNet152 (52 residual-block units), 4..52 EPs."""
from __future__ import annotations


from repro.core import simulate, synthetic_database
from benchmarks.common import write_csv

EP_COUNTS = (4, 8, 13, 26, 52)


def run() -> list:
    db = synthetic_database("resnet152")
    rows = []
    for n in EP_COUNTS:
        for seed in (0, 1):
            r = simulate(db, n, scheduler="odin", alpha=10,
                         num_queries=1000, freq_period=10, duration=10,
                         seed=seed)
            rows.append({
                "num_eps": n, "seed": seed,
                "mean_latency": r.latencies.mean(),
                "p99_latency": r.tail_latency(99),
                "mean_throughput": r.throughputs.mean(),
                "peak_throughput": r.peak_throughput,
                "throughput_frac_of_peak":
                    r.throughputs.mean() / r.peak_throughput,
            })
    write_csv("fig10_scalability", rows)
    return rows
