"""Shared benchmark utilities: databases, the 9 paper settings, CSV I/O."""
from __future__ import annotations

import csv
import os
import time
from typing import Dict, Iterable, List, Optional, Sequence

import numpy as np

from repro.core import PAPER_SETTINGS, simulate, synthetic_database

RESULTS_DIR = os.environ.get("REPRO_RESULTS", "results/benchmarks")

# Paper evaluation constants (§4.1/§4.2)
MODELS = ("vgg16", "resnet50")
NUM_EPS = 4
NUM_QUERIES = int(os.environ.get("REPRO_QUERIES", "2000"))
SEEDS = (0, 1, 2)

SCHEDULERS = {
    "odin_a10": dict(scheduler="odin", alpha=10),
    "odin_a2": dict(scheduler="odin", alpha=2),
    "lls": dict(scheduler="lls"),
    "hybrid": dict(scheduler="hybrid", alpha=10),
}


def db_for(model: str):
    return synthetic_database(model, seed=0)


def run_matrix(model: str, schedulers: Dict[str, dict] = SCHEDULERS,
               settings: Iterable = PAPER_SETTINGS,
               num_eps: int = NUM_EPS,
               num_queries: int = NUM_QUERIES,
               seeds: Sequence[int] = SEEDS,
               workload: str = "closed",
               workload_kwargs: Optional[dict] = None,
               chunking: bool = True) -> List[dict]:
    """One row per (scheduler, freq, dur, seed) with summary metrics.

    ``workload``/``workload_kwargs`` select the arrival process
    (``repro.workloads``); the default closed loop reproduces the paper's
    saturated stream.  Every row carries the queue-aware columns
    (offered/achieved load, queueing delay, queue depth) — zero /
    degenerate under the closed loop, load-bearing for open-loop sweeps.

    ``chunking=False`` times the scalar per-query tick instead of the
    batch-granular fast path — results are identical (closed loop:
    bit-identical); ``benchmarks/runner_bench.py`` uses the pair to
    track the fast path's speedup.
    """
    db = db_for(model)
    rows = []
    for name, kw in schedulers.items():
        for freq, dur in settings:
            for seed in seeds:
                t0 = time.perf_counter()
                r = simulate(db, num_eps, num_queries=num_queries,
                             freq_period=freq, duration=dur, seed=seed,
                             workload=workload,
                             workload_kwargs=workload_kwargs,
                             chunking=chunking, **kw)
                rows.append({
                    "model": model, "scheduler": name,
                    "freq": freq, "dur": dur, "seed": seed,
                    "mean_latency": r.latencies.mean(),
                    "p50_latency": float(np.percentile(r.latencies, 50)),
                    "p99_latency": r.tail_latency(99),
                    "mean_throughput": r.throughputs.mean(),
                    "steady_throughput": r.steady_throughput,
                    "peak_throughput": r.peak_throughput,
                    "rebalances": r.num_rebalances,
                    "serial_frac": r.rebalance_fraction,
                    "mean_mitigation": (np.mean(r.mitigation_lengths)
                                        if r.mitigation_lengths else 0.0),
                    "sim_wall_s": time.perf_counter() - t0,
                    "workload": r.workload,
                    "offered_load": r.offered_load,
                    "achieved_load": r.achieved_load,
                    "mean_queue_delay": r.mean_queue_delay,
                    "p99_queue_delay": float(
                        np.percentile(r.queue_delays, 99)),
                    "max_queue_depth": int(r.queue_depths.max()),
                })
    return rows


def write_csv(name: str, rows: List[dict]) -> str:
    os.makedirs(RESULTS_DIR, exist_ok=True)
    path = os.path.join(RESULTS_DIR, name + ".csv")
    if rows:
        with open(path, "w", newline="") as f:
            w = csv.DictWriter(f, fieldnames=list(rows[0].keys()))
            w.writeheader()
            w.writerows(rows)
    return path


def agg(rows: List[dict], key: str, **filters) -> float:
    sel = [r[key] for r in rows
           if all(r[k] == v for k, v in filters.items())]
    return float(np.mean(sel)) if sel else float("nan")
