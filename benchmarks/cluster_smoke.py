"""CI cluster smoke: 4 replicas, bursty traffic, one interfered replica.

Runs the acceptance scenario through every built-in router — a fleet of
4 simulated pipeline replicas under a bursty (MMPP) arrival process
with the paper's heaviest interference setting (freq=2, dur=100) scoped
to replica 2 — writes the per-replica + fleet ClusterTrace rows to
``results/benchmarks/cluster_smoke.csv``, and fails unless
interference-aware routing pays off:

* ``odin_aware`` fleet p99 <= ``round_robin`` fleet p99 (the gate), and
* every row is finite and each run served every query exactly once.

    REPRO_CLUSTER_QUERIES=2000 PYTHONPATH=src python -m benchmarks.cluster_smoke
"""
from __future__ import annotations

import csv
import dataclasses
import math
import os
import sys

from benchmarks.common import RESULTS_DIR, db_for
from repro import api
from repro.cluster import available_routers
from repro.core import generate_events

NUM_QUERIES = int(os.environ.get("REPRO_CLUSTER_QUERIES", "2000"))
NUM_REPLICAS = 4
VICTIM = 2          # the replica the interference events are scoped to

REQUIRED = ("p50_latency", "p99_latency", "mean_queue_delay",
            "steady_throughput")


def main() -> int:
    db = db_for("vgg16")
    # One declaration per run (docs/API.md): the probe and the sweep
    # differ only in the fields .replace() swaps out.
    cap = api.run(api.RunSpec(
        db=db, num_eps=NUM_REPLICAS, num_queries=10, events=(),
        scheduler=api.SchedulerSpec(name="none"))).peak_throughput
    events = [dataclasses.replace(ev, replica=VICTIM)
              for ev in generate_events(NUM_QUERIES // NUM_REPLICAS,
                                        NUM_REPLICAS, db.num_scenarios,
                                        2, 100, seed=5)]
    workload_kwargs = dict(burst_rate=4.0 * cap, base_rate=0.5 * cap,
                           mean_burst=3000.0, mean_gap=5000.0, seed=7)
    base = api.RunSpec(
        db=db, num_eps=NUM_REPLICAS, num_queries=NUM_QUERIES,
        events=events,
        scheduler=api.SchedulerSpec(name="odin", alpha=10),
        workload=api.WorkloadSpec(name="bursty",
                                  kwargs=workload_kwargs),
        cluster=api.ClusterSpec(num_replicas=NUM_REPLICAS))

    rows, p99 = [], {}
    for router in available_routers():
        ct = api.run(base.replace(
            cluster=api.ClusterSpec(num_replicas=NUM_REPLICAS,
                                    router=router)))
        assert ct.replica_counts.sum() == NUM_QUERIES
        p99[router] = ct.summary()["p99_latency_s"]
        for row in ct.rows():
            rows.append({"num_queries": NUM_QUERIES, **row})
        print(f"{router:18s} fleet p99 {p99[router]:10.2f}  "
              f"victim share {ct.replica_counts[VICTIM] / NUM_QUERIES:.2f}  "
              f"rebalances {sum(t.num_rebalances for t in ct.replicas)}")

    os.makedirs(RESULTS_DIR, exist_ok=True)
    path = os.path.join(RESULTS_DIR, "cluster_smoke.csv")
    with open(path, "w", newline="") as f:
        w = csv.DictWriter(f, fieldnames=list(rows[0].keys()))
        w.writeheader()
        w.writerows(rows)

    failed = []
    bad = [(r["scope"], col) for r in rows for col in REQUIRED
           if col in r and isinstance(r[col], float)
           and not math.isfinite(r[col]) and r["queries"] > 0]
    if bad:
        failed.append(f"non-finite columns: {bad}")
    if p99["odin_aware"] > p99["round_robin"]:
        failed.append(f"odin_aware p99 {p99['odin_aware']:.2f} > "
                      f"round_robin p99 {p99['round_robin']:.2f}")
    if failed:
        print("cluster_smoke FAILED: " + "; ".join(failed))
        return 1
    print(f"cluster_smoke OK -> {path}")
    return 0


if __name__ == "__main__":
    sys.exit(main())
