"""CI faults smoke: crash + flaky overload, recovery machinery on/off.

Runs one deterministic fault scenario — a mid-run replica crash
(time-indexed, so the replica recovers) plus a fleet-wide flaky window —
through three fleet configurations:

* ``reference``  — the same traffic with no faults at all,
* ``no_retry``   — faults injected, zero retry budget (failures final),
* ``recovered``  — faults injected, retry budget + backoff and a
  sensitive circuit breaker (``failure_threshold=2``).

and gates on the recovery machinery actually paying for itself:

* ``recovered`` availability >= 99% while ``no_retry`` loses queries,
* ``recovered`` goodput strictly above ``no_retry`` goodput,
* p99 of *successful* queries within ``P99_MARGIN`` of the fault-free
  reference (retries must not wreck the tail), and
* the ``recovered`` run is bit-deterministic (two runs, equal summaries).

Writes one row per configuration to
``results/benchmarks/faults_smoke.csv``.

    REPRO_FAULTS_QUERIES=600 PYTHONPATH=src python -m benchmarks.faults_smoke
"""
from __future__ import annotations

import math
import os
import sys

from benchmarks.common import db_for, write_csv
from repro.cluster import simulate_cluster
from repro.core import simulate
from repro.faults import FaultEvent, FaultPlan

NUM_QUERIES = int(os.environ.get("REPRO_FAULTS_QUERIES", "600"))
NUM_REPLICAS = 3
UTILIZATION = 0.6           # offered load as a fraction of fleet peak
P99_MARGIN = 1.5            # recovered p99 <= margin * reference p99

COLS = ("availability", "goodput_qps", "p99_latency_s", "num_failed",
        "num_retried", "num_hedged", "wasted_work_frac", "downtime_s")


def fault_plan() -> FaultPlan:
    """Crash replica 1 mid-run, then a fleet-wide flaky window.

    Time-indexed so the crash window *ends*: the replica restarts,
    re-warms, and must rejoin the fleet (docs/FAULTS.md)."""
    return FaultPlan(events=[
        FaultEvent("crash", start=400.0, duration=800.0, replica=1),
        FaultEvent("flaky", start=1500.0, duration=900.0, p=0.5),
    ], seed=0, time_indexed=True)


def main() -> int:
    db = db_for("vgg16")
    peak = simulate(db, NUM_REPLICAS, scheduler="none", events=[],
                    num_queries=10).peak_throughput
    wl = dict(rate=UTILIZATION * NUM_REPLICAS * peak, seed=11)
    common = dict(scheduler="odin", num_queries=NUM_QUERIES,
                  workload="poisson", workload_kwargs=wl,
                  router="least_outstanding")
    recover_kw = dict(
        retries=dict(max_retries=4, backoff=1.0, jitter=0.5),
        health_kwargs=dict(failure_threshold=2, cooldown=50.0))

    runs = {
        "reference": simulate_cluster(db, NUM_REPLICAS, NUM_REPLICAS,
                                      **common),
        "no_retry": simulate_cluster(db, NUM_REPLICAS, NUM_REPLICAS,
                                     faults=fault_plan(),
                                     retries=dict(max_retries=0),
                                     **common),
        "recovered": simulate_cluster(db, NUM_REPLICAS, NUM_REPLICAS,
                                      faults=fault_plan(), **recover_kw,
                                      **common),
    }
    rerun = simulate_cluster(db, NUM_REPLICAS, NUM_REPLICAS,
                             faults=fault_plan(), **recover_kw, **common)

    rows = []
    for name, ct in runs.items():
        s = ct.summary()
        rows.append({"config": name, "num_queries": NUM_QUERIES,
                     **{c: s[c] for c in COLS}})
        print(f"{name:10s} avail {s['availability']:.4f}  "
              f"goodput {s['goodput_qps']:.5f}  "
              f"p99 {s['p99_latency_s']:8.2f}  "
              f"failed {s['num_failed']:3.0f}  "
              f"retried {s['num_retried']:3.0f}  "
              f"downtime {s['downtime_s']:7.0f}")
    path = write_csv("faults_smoke", rows)

    ref, bare, rec = (runs[k].summary()
                      for k in ("reference", "no_retry", "recovered"))
    failed = []
    if rec["availability"] < 0.99:
        failed.append(f"recovered availability {rec['availability']:.4f} "
                      "< 0.99")
    if bare["num_failed"] <= 0:
        failed.append("no_retry run lost no queries — the fault plan "
                      "never bit; the comparison is vacuous")
    if not rec["goodput_qps"] > bare["goodput_qps"]:
        failed.append(f"recovered goodput {rec['goodput_qps']:.5f} not "
                      f"above no_retry {bare['goodput_qps']:.5f}")
    if rec["p99_latency_s"] > P99_MARGIN * ref["p99_latency_s"]:
        failed.append(f"recovered p99 {rec['p99_latency_s']:.2f} > "
                      f"{P99_MARGIN}x fault-free "
                      f"{ref['p99_latency_s']:.2f}")
    s1, s2 = runs["recovered"].summary(), rerun.summary()
    drift = [k for k in s1
             if s1[k] != s2[k]
             and not (isinstance(s1[k], float) and math.isnan(s1[k])
                      and math.isnan(s2[k]))]
    if drift:
        failed.append(f"recovered run not deterministic: {drift}")
    bad = [(r["config"], c) for r in rows for c in COLS
           if isinstance(r[c], float) and not math.isfinite(r[c])]
    if bad:
        failed.append(f"non-finite columns: {bad}")

    if failed:
        print("faults_smoke FAILED: " + "; ".join(failed))
        return 1
    print(f"faults_smoke OK -> {path}")
    return 0


if __name__ == "__main__":
    sys.exit(main())
