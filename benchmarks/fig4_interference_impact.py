"""Fig. 4 / Table 1: per-scenario slowdown of a single VGG16 layer."""
from __future__ import annotations

from repro.core import synthetic_database
from benchmarks.common import write_csv


def run() -> list:
    db = synthetic_database("vgg16")
    layer = 5                                 # a mid-network conv layer
    base = db.layer_time(layer, 0)
    rows = []
    for k in range(1, db.num_scenarios + 1):
        rows.append({
            "scenario": db.scenario_names[k],
            "layer_time": db.layer_time(layer, k),
            "slowdown_x": db.layer_time(layer, k) / base,
        })
    write_csv("fig4_interference_impact", rows)
    return rows
