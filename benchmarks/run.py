"""Benchmark harness: one module per paper table/figure.

    PYTHONPATH=src python -m benchmarks.run [--quick]

Writes per-figure CSVs to results/benchmarks/ and prints a
``name,value,derived`` summary CSV to stdout.
"""
from __future__ import annotations

import argparse
import os
import time


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--quick", action="store_true",
                    help="fewer queries/seeds (CI mode)")
    args = ap.parse_args()
    if args.quick:
        os.environ["REPRO_QUERIES"] = "600"

    # imports after env so common.py picks the settings up
    from benchmarks import (
        fig1_motivation,
        fig4_interference_impact,
        fig5_latency,
        fig6_throughput,
        fig7_tail_latency,
        fig8_overhead,
        fig9_qos,
        fig10_scalability,
        kernel_bench,
        roofline_report,
    )

    t0 = time.time()
    print("name,value,derived")

    rows1 = fig1_motivation.run()
    peak = rows1[0]["throughput"]
    odin = next(r for r in rows1 if r["config"] == "odin_rebalanced")
    print(f"fig1_odin_recovered_frac,{odin['throughput'] / peak:.3f},"
          f"search_wall={odin['search_wall_s'] * 1e3:.1f}ms")

    rows4 = fig4_interference_impact.run()
    print(f"fig4_max_slowdown_x,{max(r['slowdown_x'] for r in rows4):.2f},"
          f"scenarios={len(rows4)}")

    matrix = fig5_latency.run()
    s5 = fig5_latency.summarize(matrix)
    print(f"fig5_odin10_latency_gain_pct,{s5['odin_a10_vs_lls_pct']:.1f},"
          f"paper=15.8")
    print(f"fig5_odin2_latency_gain_pct,{s5['odin_a2_vs_lls_pct']:.1f},"
          f"paper=14.1")

    fig6_throughput.run(matrix)
    s6 = fig6_throughput.summarize(matrix)
    print(f"fig6_odin10_throughput_gain_pct,{s6['odin_a10_vs_lls_pct']:.1f},"
          f"paper=19 (steady-state)")
    print(f"fig6_odin10_throughput_incl_exploration_pct,"
          f"{s6['odin_a10_vs_lls_incl_exploration_pct']:.1f},"
          f"includes Fig8 exploration overhead")

    fig7_tail_latency.run(matrix)
    s7 = fig7_tail_latency.summarize(matrix)
    print(f"fig7_odin10_tail_gain_pct,{s7['odin_a10_vs_lls_pct']:.1f},"
          f"paper=14")

    rows8 = fig8_overhead.run(matrix)
    hi = max(r["rebalance_pct"] for r in rows8 if r["scheduler"] == "odin_a10")
    lo = min(r["rebalance_pct"] for r in rows8 if r["scheduler"] == "odin_a10")
    print(f"fig8_odin10_overhead_pct_range,{lo:.0f}-{hi:.0f},"
          f"freq2_high_freq100_low")

    rows9 = fig9_qos.run()
    v85 = [r["violations_vs_peak"] for r in rows9
           if r["scheduler"] == "odin_a10" and r["slo_level"] <= 0.85]
    print(f"fig9_odin10_viol_at_slo<=85,{100 * sum(v85) / len(v85):.0f}%,"
          f"paper=<20% (DB-calibration dependent)")

    rows10 = fig10_scalability.run()
    lat_spread = (max(r['mean_latency'] for r in rows10)
                  / min(r['mean_latency'] for r in rows10))
    print(f"fig10_latency_spread_4to52eps,{lat_spread:.2f},"
          f"paper=flat (~1.0)")

    from benchmarks import measured_db_eval
    rows_m = measured_db_eval.run()
    if rows_m:
        sm = measured_db_eval.summarize(rows_m)
        print(f"measured_db_odin10_throughput_gain_pct,"
              f"{sm['throughput_gain_pct']:.1f},paper=19 (real stressors)")
        print(f"measured_db_odin10_latency_gain_pct,"
              f"{sm['latency_gain_pct']:.1f},paper=15.8 (real stressors)")

    from benchmarks import ablation_alpha
    rows_a = ablation_alpha.run()
    best = max(rows_a, key=lambda r: r["steady_throughput"])
    print(f"ablation_best_alpha,{best['alpha']},by steady throughput")

    kernel_bench.run()
    roofline_report.run()
    nroof = len(roofline_report.run())
    print(f"roofline_rows,{nroof},see results/benchmarks/roofline.csv")
    print(f"total_wall_s,{time.time() - t0:.0f},")


if __name__ == "__main__":
    main()
