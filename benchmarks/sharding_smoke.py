"""CI sharding smoke: a mesh-contention episode on an 8-device mesh.

Runs the docs/SHARDING.md acceptance scenario through the spec path
(docs/API.md): a vgg16 pipeline sharded over ``MeshSpec(devices=8,
coll_cost=0.5)`` — collective costs heavy enough that slice placement
matters — under the paper's interference timeline plus one ``kind="mesh"``
event inflating collective time 6x mid-run.  Three schedulers:

* ``odin``  — (boundary, slice) moves: the mesh-aware explorer,
* ``lls``   — boundary-only moves on the fixed balanced assignment,
* ``none``  — the static balanced config.

Writes one summary row per scheduler to
``results/benchmarks/sharding_smoke.csv`` and fails unless slicing
pays off:

* odin p99 <= lls p99 (slice moves never lose to boundary-only),
* odin p99 <  static p99 (strict: the episode must be mitigated),
* odin committed at least one mesh resize, and
* the odin run is deterministic (a rerun is bit-identical).

    REPRO_SHARDING_QUERIES=600 PYTHONPATH=src python -m benchmarks.sharding_smoke
"""
from __future__ import annotations

import csv
import math
import os
import sys

import numpy as np

from benchmarks.common import RESULTS_DIR, db_for
from repro import api
from repro.core import InterferenceEvent, generate_events

NUM_QUERIES = int(os.environ.get("REPRO_SHARDING_QUERIES", "600"))
NUM_EPS = 4
MESH = api.MeshSpec(devices=8, coll_cost=0.5)
MESH_FACTOR = 6.0

SCHEDULERS = ("odin", "lls", "none")


def mesh_events(num_queries: int):
    """The paper's timeline plus one mesh-contention episode mid-run."""
    evs = list(generate_events(num_queries, NUM_EPS, 12, 20, 10, seed=3))
    evs.append(InterferenceEvent(start=num_queries // 3,
                                 duration=num_queries // 4, ep=0,
                                 scenario=0, kind="mesh",
                                 factor=MESH_FACTOR))
    return evs


def main() -> int:
    db = db_for("vgg16")
    base = api.RunSpec(db=db, num_eps=NUM_EPS, num_queries=NUM_QUERIES,
                       events=mesh_events(NUM_QUERIES), mesh=MESH)

    rows, p99, traces = [], {}, {}
    for sched in SCHEDULERS:
        t = api.run(base.replace(
            scheduler=api.SchedulerSpec(name=sched)))
        traces[sched] = t
        s = t.summary()
        p99[sched] = float(np.percentile(t.latencies, 99))
        rows.append({
            "scheduler": sched,
            "num_queries": NUM_QUERIES,
            "mesh_devices": t.mesh_devices,
            "p50_latency": float(np.percentile(t.latencies, 50)),
            "p99_latency": p99[sched],
            "steady_throughput": s["steady_throughput_qps"],
            "num_rebalances": t.num_rebalances,
            "num_mesh_resizes": t.num_mesh_resizes,
            "mean_collective_frac": s["mean_collective_frac"],
            "p99_collective_frac": s["p99_collective_frac"],
        })
        print(f"{sched:6s} p99 {p99[sched]:10.2f}  "
              f"rebalances {t.num_rebalances:3d}  "
              f"mesh resizes {t.num_mesh_resizes:3d}  "
              f"coll frac {s['mean_collective_frac']:.3f}")

    rerun = api.run(base.replace(scheduler=api.SchedulerSpec(name="odin")))

    os.makedirs(RESULTS_DIR, exist_ok=True)
    path = os.path.join(RESULTS_DIR, "sharding_smoke.csv")
    with open(path, "w", newline="") as f:
        w = csv.DictWriter(f, fieldnames=list(rows[0].keys()))
        w.writeheader()
        w.writerows(rows)

    failed = []
    bad = [(r["scheduler"], k) for r in rows for k, v in r.items()
           if isinstance(v, float) and not math.isfinite(v)]
    if bad:
        failed.append(f"non-finite columns: {bad}")
    if p99["odin"] > p99["lls"]:
        failed.append(f"(boundary, slice) p99 {p99['odin']:.2f} > "
                      f"boundary-only p99 {p99['lls']:.2f}")
    if not p99["odin"] < p99["none"]:
        failed.append(f"odin p99 {p99['odin']:.2f} does not beat "
                      f"static p99 {p99['none']:.2f}")
    if traces["odin"].num_mesh_resizes < 1:
        failed.append("odin committed no mesh resize")
    if not (np.array_equal(rerun.latencies, traces["odin"].latencies)
            and rerun.mesh_trace == traces["odin"].mesh_trace):
        failed.append("odin rerun is not bit-identical")
    if failed:
        print("sharding_smoke FAILED: " + "; ".join(failed))
        return 1
    print(f"sharding_smoke OK -> {path}")
    return 0


if __name__ == "__main__":
    sys.exit(main())
