"""CI smoke benchmark: a tiny ``run_matrix`` through the full
simulate -> PipelineTrace -> CSV path.

    REPRO_QUERIES=200 PYTHONPATH=src python -m benchmarks.smoke

Two (freq, dur) settings x one seed, closed-loop plus one open-loop
bursty sweep, finishing in seconds — so a regression anywhere on the
benchmark path (simulator, workloads, trace metrics, CSV schema) fails
CI instead of surfacing the next time someone runs the full figure
suite.  Exits non-zero if required columns are missing or non-finite.
"""
from __future__ import annotations

import math
import sys

from benchmarks.common import run_matrix, write_csv

SETTINGS = ((10, 10), (100, 10))
SCHEDULERS = {
    "odin_a10": dict(scheduler="odin", alpha=10),
    "lls": dict(scheduler="lls"),
}
# Columns every row must carry with finite values: the pre-workloads
# summary metrics plus the queue-aware additions.
REQUIRED = (
    "mean_latency", "p50_latency", "p99_latency", "mean_throughput",
    "steady_throughput", "peak_throughput", "serial_frac",
    "offered_load", "achieved_load", "mean_queue_delay",
    "p99_queue_delay", "max_queue_depth",
)


def main() -> int:
    rows = run_matrix("vgg16", schedulers=SCHEDULERS, settings=SETTINGS,
                      seeds=(0,))
    rows += run_matrix(
        "vgg16", schedulers={"odin_a10": SCHEDULERS["odin_a10"]},
        settings=SETTINGS[:1], seeds=(0,), workload="bursty",
        workload_kwargs=dict(burst_rate=0.03, base_rate=0.002,
                             mean_burst=2000, mean_gap=2000, seed=0))
    bad = [(i, col) for i, r in enumerate(rows) for col in REQUIRED
           if col not in r or not math.isfinite(float(r[col]))]
    if bad:
        print(f"smoke FAILED: missing/non-finite columns {bad}")
        return 1
    closed = [r for r in rows if r["workload"] == "closed"]
    bursty = [r for r in rows if r["workload"] == "bursty"]
    if not closed or not bursty:
        print("smoke FAILED: expected both closed and bursty rows")
        return 1
    if any(r["mean_queue_delay"] != 0.0 for r in closed):
        print("smoke FAILED: closed-loop rows must have zero queue delay")
        return 1
    # Fast-path regression: run_matrix defaults to the chunked tick;
    # its first row must match the scalar tick column-for-column.
    scalar = run_matrix("vgg16",
                        schedulers={"odin_a10": SCHEDULERS["odin_a10"]},
                        settings=SETTINGS[:1], seeds=(0,), chunking=False)
    diverged = [c for c in REQUIRED + ("rebalances",)
                if scalar[0][c] != rows[0][c]]
    if diverged:
        print(f"smoke FAILED: chunked vs scalar diverged on {diverged}")
        return 1
    path = write_csv("smoke", rows)
    print(f"smoke OK: {len(rows)} rows -> {path}")
    return 0


if __name__ == "__main__":
    sys.exit(main())
