"""Bench-regression gate: diff BENCH_runner.json against the baseline.

``benchmarks/runner_bench.py`` measures the batch-granular fast path
(scalar-vs-chunked speedup per row, plus the 1M-query vectorized-ledger
scale row) and writes ``results/benchmarks/BENCH_runner.json``.  This
script compares that fresh report against the committed baseline under
``benchmarks/baselines/`` and fails when the perf trajectory regresses:

* the gate row's (``steady_none``) chunked speedup — a ratio of two
  wall times on the *same* machine, so it transfers across hosts — may
  not drop more than ``REPRO_BENCH_TOLERANCE`` (default 30%) below the
  baseline's;
* the scale row's *relative throughput* — its queries/s divided by the
  same run's steady-row chunked queries/s, so host speed cancels and
  the number survives the dev-machine -> CI-runner hop — may not drop
  more than the same tolerance.  Raw qps for both runs is carried in
  the diff for eyeballing but never gated (two different hosts differ
  by far more than any real regression);
* the batching row's continuous-vs-drain queue-delay ratio — a pure
  simulator quantity, deterministic across hosts — may not drop more
  than the same tolerance below the baseline's (runner_bench already
  gates its absolute floor).

The full diff is always written to ``results/benchmarks/bench_diff.json``
so CI uploads it with the other artifacts.

    PYTHONPATH=src python -m benchmarks.compare_bench

Environment:
    REPRO_BENCH_BASELINE     baseline report path
                             (default benchmarks/baselines/BENCH_runner.json)
    REPRO_BENCH_CURRENT      fresh report path
                             (default results/benchmarks/BENCH_runner.json)
    REPRO_BENCH_TOLERANCE    allowed fractional regression (default 0.30)
"""
from __future__ import annotations

import json
import os
import sys

from benchmarks.common import RESULTS_DIR

BASELINE = os.environ.get(
    "REPRO_BENCH_BASELINE", "benchmarks/baselines/BENCH_runner.json"
)
CURRENT = os.environ.get(
    "REPRO_BENCH_CURRENT", os.path.join(RESULTS_DIR, "BENCH_runner.json")
)
TOLERANCE = float(os.environ.get("REPRO_BENCH_TOLERANCE", "0.30"))


def _row(report: dict, name: str) -> dict:
    for row in report.get("rows", []):
        if row.get("row") == name:
            return row
    raise KeyError(f"report has no row {name!r}")


def compare(baseline: dict, current: dict, tolerance: float) -> list:
    """One diff entry per gated metric; ``ok=False`` marks a regression."""
    gate_row = baseline.get("gate", {}).get("row", "steady_none")
    diffs = []

    base_speedup = float(_row(baseline, gate_row)["speedup"])
    cur_speedup = float(_row(current, gate_row)["speedup"])
    diffs.append(
        {
            "metric": f"{gate_row}.speedup",
            "baseline": base_speedup,
            "current": cur_speedup,
            "ratio": cur_speedup / base_speedup,
            "ok": cur_speedup >= (1.0 - tolerance) * base_speedup,
        }
    )

    base_scale = baseline.get("scale")
    cur_scale = current.get("scale")
    if base_scale and cur_scale:
        # Normalize by each run's own steady-row throughput: the ratio
        # measures the ledger's per-query cost relative to the chunked
        # simulator on the same host, so it transfers across machines.
        base_rel = float(base_scale["chunked_qps"]) / float(
            _row(baseline, gate_row)["chunked_qps"]
        )
        cur_rel = float(cur_scale["chunked_qps"]) / float(
            _row(current, gate_row)["chunked_qps"]
        )
        diffs.append(
            {
                "metric": "scale_ledger.relative_qps",
                "baseline": base_rel,
                "current": cur_rel,
                "ratio": cur_rel / base_rel,
                "ok": cur_rel >= (1.0 - tolerance) * base_rel,
                "baseline_raw_qps": float(base_scale["chunked_qps"]),
                "current_raw_qps": float(cur_scale["chunked_qps"]),
            }
        )
    elif base_scale and not cur_scale:
        diffs.append(
            {
                "metric": "scale_ledger.relative_qps",
                "baseline": float(base_scale["chunked_qps"]),
                "current": None,
                "ratio": None,
                "ok": False,
            }
        )

    base_batch = baseline.get("batching")
    cur_batch = current.get("batching")
    if base_batch:
        base_ratio = float(base_batch["delay_ratio"])
        if cur_batch:
            cur_ratio = float(cur_batch["delay_ratio"])
            diffs.append(
                {
                    "metric": "bursty_batching.delay_ratio",
                    "baseline": base_ratio,
                    "current": cur_ratio,
                    "ratio": cur_ratio / base_ratio,
                    "ok": cur_ratio >= (1.0 - tolerance) * base_ratio,
                }
            )
        else:
            diffs.append(
                {
                    "metric": "bursty_batching.delay_ratio",
                    "baseline": base_ratio,
                    "current": None,
                    "ratio": None,
                    "ok": False,
                }
            )
    return diffs


def main() -> int:
    with open(BASELINE) as f:
        baseline = json.load(f)
    with open(CURRENT) as f:
        current = json.load(f)

    diffs = compare(baseline, current, TOLERANCE)
    report = {
        "schema": 1,
        "baseline_path": BASELINE,
        "current_path": CURRENT,
        "tolerance": TOLERANCE,
        "diffs": diffs,
    }
    os.makedirs(RESULTS_DIR, exist_ok=True)
    out = os.path.join(RESULTS_DIR, "bench_diff.json")
    with open(out, "w") as f:
        json.dump(report, f, indent=2)

    failed = []
    for d in diffs:
        cur = "missing" if d["current"] is None else f"{d['current']:.2f}"
        ratio = "" if d["ratio"] is None else f"  ({d['ratio']:.2f}x baseline)"
        print(
            f"{d['metric']:26s} baseline {d['baseline']:9.2f}  "
            f"current {cur:>9s}{ratio}  {'OK' if d['ok'] else 'REGRESSED'}"
        )
        if not d["ok"]:
            failed.append(d["metric"])
    if failed:
        print(
            f"compare_bench FAILED (>{TOLERANCE:.0%} regression): "
            + ", ".join(failed)
        )
        return 1
    print(f"compare_bench OK -> {out}")
    return 0


if __name__ == "__main__":
    sys.exit(main())
